import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
512 placeholder host devices, record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` with:
  memory_analysis (bytes/device), cost_analysis (per-device FLOPs/bytes),
  collective wire-traffic estimates (ICI vs DCN), and the roofline terms.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.dist import default_rules, mesh_context
from repro.dist.perf import PerfConfig, perf_context
from repro.launch.analytic import analytic_memory_bytes, model_flops
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.hlo_stats import hlo_op_histogram
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.specs import auto_accum_steps, build_cell
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig


def analyze_compiled(compiled, mesh, kind: str, cfg=None, cell=None, accum: int = 1) -> dict:
    """Roofline terms from the compiled artifact.

    ``cost_analysis()`` (XLA built-in) counts while bodies ONCE — useless for
    scanned models — so the primary numbers come from the loop-attributed
    static analyzer in :mod:`.hlo_cost`. Both are recorded. CPU-backend
    caveat: bf16 is emulated via f32, inflating byte counts ~2×; FLOPs and
    collective bytes are unaffected (collective buffers keep their dtype).
    """
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    stats = hlo_analyze(txt, pod_size=256)
    flops = stats["flops"]
    bytes_accessed = stats["bytes"]
    compute_s = flops / HW["peak_flops_bf16"]
    memory_s_hlo = bytes_accessed / HW["hbm_bw"]
    coll_s = stats["wire_ici"] / HW["ici_bw"] + stats["wire_dcn"] / HW["dcn_bw"]
    # TPU adjustment: XLA:CPU promotes bf16 reduction collectives to f32
    # (its bf16-AR path aborts outright); on TPU those lanes are 2-byte.
    coll_s_bf16adj = coll_s - 0.5 * stats.get("wire_f32", 0.0) / HW["ici_bw"]
    mesh_shape = dict(mesh.shape)
    if cfg is not None and cell is not None:
        mem_bytes = analytic_memory_bytes(cfg, cell, mesh_shape, accum=accum)
        mflops = model_flops(cfg, cell)
        n_chips = mesh.size
        useful_ratio = mflops / max(flops * n_chips, 1.0)
    else:
        mem_bytes, mflops, useful_ratio, n_chips = bytes_accessed, 0.0, 0.0, mesh.size
    memory_s = mem_bytes / HW["hbm_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    return {
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "total_per_device": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes
            + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_device": flops,
            "hlo_bytes_per_device": bytes_accessed,
            "analytic_bytes_per_device": mem_bytes,
            "model_flops_global": mflops,
            "useful_flops_ratio": useful_ratio,
            "memory_s_hlo_upper_bound": memory_s_hlo,
            "xla_flops_unattributed": float(ca.get("flops", 0.0)),
            "xla_bytes_unattributed": float(ca.get("bytes accessed", 0.0)),
            "collective_s_bf16adj": coll_s_bf16adj,
        },
        "collectives": {
            "wire_ici": stats["wire_ici"],
            "wire_dcn": stats["wire_dcn"],
            "per_op": stats["per_coll"],
        },
        "roofline": {**terms, "dominant": dominant},
        "hlo_ops": hlo_op_histogram(txt, top=15),
    }


# each variant: (PerfConfig, logical-rule overrides or None)
RULE_OVERRIDES = {
    # V7: attention fully data-parallel — attention is sequence-local once
    # batch shards over `data`, so TP on heads only buys activation
    # all-reduces; replicate attn weights over `model` instead.
    "v7_attn_dp": {"heads": [], "kv": [], "act_heads": [], "act_kv": []},
    # V5: decode weight-stationary layout — activations shard over `data` on
    # the EMBED dim (batch replicated); weight matmuls become local partials
    # + tiny psums instead of per-layer FSDP weight gathers.
    "v5_decode_layout": {"batch": [], "embed": [("data",)], "act_vocab": [("model",)]},
    # V8: pure FSDP data parallelism — batch shards over BOTH mesh axes
    # (1 seq/chip at train_4k → accum=1), weights stay 2D-sharded (ZeRO-3),
    # activations carry no TP at all.
    "v8_fsdp_dp": {
        "batch": [("pod", "data", "model"), ("data", "model"), ("pod", "data"), ("data",)],
        "act_heads": [], "act_kv": [], "act_mlp": [], "act_vocab": [],
    },
}

VARIANTS = {
    "baseline": PerfConfig(),
    "v1_save_ar": PerfConfig(save_dot_outputs=True),
    "v2_moe_local": PerfConfig(moe_local_dispatch=True),
    "v3_sharded_decode": PerfConfig(sharded_decode_attn=True),
    "v4_causal_chunks": PerfConfig(causal_chunk_growth=True),
    "v6_cast_early": PerfConfig(cast_weights_early=True),
    "v1_v6": PerfConfig(save_dot_outputs=True, cast_weights_early=True),
    # NOTE: cast_weights_early is excluded — refuted (XLA re-sinks the cast,
    # no HLO delta) and its bf16 grad-psum aborts XLA:CPU under shard_map.
    "optimized": PerfConfig(
        sharded_decode_attn=True, causal_chunk_growth=True, moe_local_dispatch=True,
    ),
    "optimized_v1": PerfConfig(
        sharded_decode_attn=True, causal_chunk_growth=True, moe_local_dispatch=True,
        save_dot_outputs=True,
    ),
    "v7_attn_dp": PerfConfig(),
    "v5_decode_layout": PerfConfig(sharded_decode_attn=True),
    "v1_v7": PerfConfig(save_dot_outputs=True),
    "v5_v3": PerfConfig(sharded_decode_attn=True),
    "v8_fsdp_dp": PerfConfig(cast_weights_early=True),
    "v8_noearly": PerfConfig(),
    "v9_bf16_rowpar": PerfConfig(bf16_rowparallel=True),
    "v9_v1": PerfConfig(bf16_rowparallel=True, save_dot_outputs=True),
}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str, rules=None,
             variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "kind": cell.kind,
        "variant": variant,
        "params": cfg.params_count(),
        "active_params": cfg.active_params_count(),
    }
    ok, why = cfg.shape_supported(cell)
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    accum = auto_accum_steps(mesh, cell.global_batch, cell.seq_len, cfg=cfg) if cell.kind == "train" else 1
    if rules is None and variant in RULE_OVERRIDES or variant.replace("v1_", "").replace("v5_", "v5_decode_layout") in RULE_OVERRIDES:
        pass
    if rules is None:
        ov = {}
        if variant in ("v7_attn_dp", "v1_v7"):
            ov.update(RULE_OVERRIDES["v7_attn_dp"])
        if variant in ("v5_decode_layout", "v5_v3"):
            ov.update(RULE_OVERRIDES["v5_decode_layout"])
        if variant in ("v8_fsdp_dp", "v8_noearly"):
            ov.update(RULE_OVERRIDES["v8_fsdp_dp"])
        if ov:
            rules = default_rules().override(**ov)
    t0 = time.time()
    with perf_context(VARIANTS[variant]), mesh_context(mesh, rules):
        recipe = build_cell(
            cfg, cell, mesh, TrainConfig(opt=OptimizerConfig(), accum_steps=0), rules
        )
        jitted = jax.jit(
            recipe.fn,
            in_shardings=recipe.in_shardings,
            donate_argnums=recipe.donate_argnums,
        )
        lowered = jitted.lower(*recipe.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    rec["accum_steps"] = accum
    rec.update(analyze_compiled(compiled, mesh, cell.kind, cfg=cfg, cell=cell, accum=accum))
    rec["status"] = "ok"
    rec["t_lower_s"] = round(t_lower, 2)
    rec["t_compile_s"] = round(t_compile, 2)
    # the dry-run contract: print the proofs
    mem = rec["memory"]
    print(
        f"[{arch} × {shape} × {mesh_name} × {variant}] OK  "
        f"args={mem['argument_bytes']/2**30:.2f}GiB temp={mem['temp_bytes']/2**30:.2f}GiB "
        f"flops/dev={rec['cost']['flops_per_device']:.3e} "
        f"dominant={rec['roofline']['dominant']} "
        f"(c={rec['roofline']['compute_s']*1e3:.1f}ms m={rec['roofline']['memory_s']*1e3:.1f}ms "
        f"coll={rec['roofline']['collective_s']*1e3:.1f}ms)",
        flush=True,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
                suffix = "" if args.variant == "baseline" else f"__{args.variant}"
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}{suffix}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[{arch} × {shape} × {mesh_name}] cached", flush=True)
                    continue
                try:
                    rec = run_cell(arch, shape, multi_pod, args.out, variant=args.variant)
                except Exception as e:
                    traceback.print_exc()
                    rec = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": mesh_name,
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                    }
                    failures.append((arch, shape, mesh_name))
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=str)
    if failures:
        print(f"\nFAILURES ({len(failures)}):")
        for f_ in failures:
            print("  ", f_)
        raise SystemExit(1)
    print("\nAll requested dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
