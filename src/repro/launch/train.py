"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck

``--reduced`` runs the smoke-scale config (CPU container); full configs are
for real accelerator fleets. ``--resume`` restores from the BVLSM store
(params, optimizer, step, data cursor).
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig
from repro.training.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--sync-ckpt", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--d-model", type=int, default=0, help="override reduced d_model")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(**({"d_model": args.d_model} if args.d_model else {}))
    tcfg = TrainerConfig(
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_interval=args.ckpt_interval,
        ckpt_async=not args.sync_ckpt,
        train=TrainConfig(
            opt=OptimizerConfig(lr=args.lr, warmup_steps=20, total_steps=max(args.steps, 100)),
            accum_steps=args.accum,
        ),
    )
    trainer = Trainer(cfg, tcfg)
    try:
        result = trainer.run()
        print("result:", {k: v for k, v in result.items() if k != "metrics"})
        if result["metrics"]:
            first, last = result["metrics"][0], result["metrics"][-1]
            print(f"loss: {first.get('loss'):.4f} -> {last.get('loss'):.4f}")
        print("checkpoint engine stats:", trainer.store.stats())
    finally:
        trainer.close()


if __name__ == "__main__":
    main()
