"""Parse compiled (post-SPMD) HLO text for collective traffic.

``collective_stats`` sums, per collective kind, the per-device buffer bytes
and converts them to estimated per-chip *wire* traffic using ring-algorithm
corrections:

    all-gather         (n-1)/n · output_bytes   received per chip
    reduce-scatter     (n-1)/n · input_bytes    sent per chip
    all-reduce         2·(n-1)/n · buffer_bytes (RS + AG phases)
    all-to-all         (n-1)/n · buffer_bytes
    collective-permute buffer_bytes

Groups whose device ids span more than one pod (id // pod_size differs) are
charged to DCN instead of ICI. Shapes in compiled HLO are already
per-partition, so buffer sizes are per-chip.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "f8e5m2": 1,
    "f8e4m3fn": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^=]*\}|\[[\d,]+\]<=\[[\d,]+\][^,\s]*)")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_info(line: str, pod_size: int) -> tuple[int, bool]:
    """Returns (group_size, crosses_pod)."""
    m = _GROUPS_RE.search(line)
    if m:
        spec = m.group(1)
        if spec.startswith("{{"):
            first = spec[2:].split("}", 1)[0]
            ids = [int(x) for x in first.split(",") if x.strip()]
            size = len(ids)
            crosses = len({i // pod_size for i in ids}) > 1
            return max(size, 1), crosses
        # iota format: [g,n]<=[...]  → groups of size n
        m2 = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](T\([\d,]+\))?", spec)
        if m2:
            g, n = int(m2.group(1)), int(m2.group(2))
            dims = [int(x) for x in m2.group(3).split(",")]
            total = 1
            for d in dims:
                total *= d
            # conservative: group crosses pods iff contiguous blocks of n ids
            # would span a pod boundary under the (possibly transposed) iota.
            trans = m2.group(4)
            if trans:
                # reconstruct the permuted id list and check the first group
                perm = [int(x) for x in trans[2:-1].split(",")]
                import numpy as np

                ids = np.arange(total).reshape(dims).transpose(perm).reshape(-1)
                first = ids[:n]
                crosses = len({int(i) // pod_size for i in first}) > 1
            else:
                crosses = n > pod_size or (total > pod_size and n > 1 and total // n < total / pod_size)
                # contiguous ids: group spans pods only if n > pod_size
                crosses = n > pod_size
            return n, crosses
    m = _SRC_TGT_RE.search(line)
    if m:
        pairs = m.group(1)
        crosses = False
        for pair in re.findall(r"\{(\d+),(\d+)\}", pairs):
            if int(pair[0]) // pod_size != int(pair[1]) // pod_size:
                crosses = True
        return 2, crosses
    return 1, False


def collective_stats(hlo_text: str, pod_size: int = 256) -> dict:
    out = {
        "per_op": defaultdict(lambda: {"count": 0, "bytes": 0, "wire_ici": 0.0, "wire_dcn": 0.0}),
        "total_bytes": 0,
        "wire_ici": 0.0,
        "wire_dcn": 0.0,
    }
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        # avoid double counting async start/done pairs: only count -start or sync
        if "-done(" in line:
            continue
        type_str, op = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        n, crosses = _group_info(line, pod_size)
        if n <= 1:
            continue
        ring = (n - 1) / n
        if op == "all-gather":
            wire = ring * nbytes  # output is the gathered buffer
        elif op == "reduce-scatter":
            # HLO shows the scattered OUTPUT; per-chip input = n·out, and a
            # ring sends (n-1)/n of the input → (n-1)·out bytes on the wire.
            wire = (n - 1) * nbytes
        elif op == "all-reduce":
            wire = 2 * ring * nbytes
        elif op == "all-to-all":
            wire = ring * nbytes
        else:  # collective-permute
            wire = nbytes
        rec = out["per_op"][op]
        rec["count"] += 1
        rec["bytes"] += nbytes
        if crosses:
            rec["wire_dcn"] += wire
            out["wire_dcn"] += wire
        else:
            rec["wire_ici"] += wire
            out["wire_ici"] += wire
        out["total_bytes"] += nbytes
    out["per_op"] = {k: dict(v) for k, v in out["per_op"].items()}
    return out


def hlo_op_histogram(hlo_text: str, top: int = 25) -> list[tuple[str, int]]:
    counts: dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s*(?:\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+([a-z][\w-]*)\(", hlo_text):
        counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
