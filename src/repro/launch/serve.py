"""Serving launcher: batched decode with the BVLSM-style paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    params = jax.tree.map(lambda p: p.astype("bfloat16") if p.dtype == np.float32 else p, params)

    engine = ServingEngine(cfg, params, max_batch=args.max_batch, max_len=256)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=args.prompt_len).astype(np.int32)
        engine.submit(Request(rid, prompt, max_new_tokens=args.max_new))
    done = engine.run_until_drained()
    print("served:", engine.metrics())
    for r in done[:3]:
        print(f"  req {r.req_id}: {len(r.tokens)} tokens, first 8 = {r.tokens[:8]}")


if __name__ == "__main__":
    main()
