"""Per-(arch × shape-cell) input specs and lowering recipes.

``input_specs(cfg, cell)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation);
``build_cell`` packages (step_fn, abstract args, shardings, donation) for
``jax.jit(...).lower(...)`` — used by both the dry-run and the roofline
harness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist import Axes, tree_shardings
from repro.models import build_model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import (
    TrainConfig,
    abstract_cache,
    abstract_params,
    abstract_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    state_axes,
)

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> tuple[dict, dict]:
    """(ShapeDtypeStructs, logical Axes) for the data batch of a cell."""
    B, S = cell.global_batch, cell.seq_len
    sds = {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }
    axes = {
        "tokens": Axes("batch", "seq"),
        "labels": Axes("batch", "seq"),
    }
    if cfg.family == "vlm":
        sds["vision_embeds"] = SDS((B, cfg.n_vision_patches, cfg.d_model), jnp.bfloat16)
        axes["vision_embeds"] = Axes("batch", None, "embed")
    if cfg.family == "audio":
        sds["enc_embeds"] = SDS((B, cfg.enc_len, cfg.d_model), jnp.bfloat16)
        axes["enc_embeds"] = Axes("batch", None, "embed")
    return sds, axes


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Public helper: all abstract inputs for this cell (dry-run contract)."""
    sds, _ = batch_specs(cfg, cell)
    if cell.kind == "decode":
        sds = {"tokens": SDS((cell.global_batch, 1), jnp.int32)}
    return sds


@dataclass
class CellRecipe:
    fn: Callable
    args: tuple
    in_shardings: tuple
    donate_argnums: tuple
    kind: str
    static_info: dict = field(default_factory=dict)


def _batch_shards(mesh, B: int) -> int:
    """How many ways the batch dim will actually shard under the rules."""
    for axes in (("pod", "data"), ("data",), ("pod",)):
        if all(a in mesh.shape for a in axes):
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if B % n == 0:
                return n
    return 1


def auto_accum_steps(mesh, B: int, S: int, target_tokens: int = 8192, cfg=None) -> int:
    """Pick gradient-accumulation steps so each microstep's per-chip token
    count stays ≈ target (bounds the L×(b,T,d) remat carry stack). With a
    model config, the target shrinks so the bf16 carry stack stays ≤ ~3 GiB
    (104B-scale models need 1-seq microsteps)."""
    if cfg is not None and cfg.n_layers and cfg.d_model:
        carry_budget = 3 << 30
        by_bytes = carry_budget // (cfg.n_layers * cfg.d_model * 2)
        target_tokens = max(min(target_tokens, by_bytes), 1024)
    local = B // _batch_shards(mesh, B)
    for cand in range(1, local + 1):  # smallest accumulation that fits
        if local % cand == 0 and (local // cand) * S <= target_tokens:
            return cand
    return local


def build_cell(
    cfg: ModelConfig,
    cell: ShapeCell,
    mesh,
    train_cfg: TrainConfig | None = None,
    rules=None,
) -> CellRecipe:
    model = build_model(cfg)
    train_cfg = train_cfg or TrainConfig()
    B, S = cell.global_batch, cell.seq_len
    q_chunk = 2048 if S > 8192 else max(S, 128)

    if cell.kind == "train":
        if train_cfg.accum_steps == 0:  # auto
            from dataclasses import replace

            train_cfg = replace(train_cfg, accum_steps=auto_accum_steps(mesh, B, S, cfg=cfg))
        step = make_train_step(model, train_cfg)
        st_sds = abstract_state(model, train_cfg.opt)
        st_ax = state_axes(model, train_cfg.opt, st_sds)
        b_sds, b_ax = batch_specs(cfg, cell)
        in_sh = (
            tree_shardings(mesh, st_sds, st_ax, rules),
            tree_shardings(mesh, b_sds, b_ax, rules),
        )
        return CellRecipe(step, (st_sds, b_sds), in_sh, (0,), "train")

    if cell.kind == "prefill":
        step = make_prefill_step(model, q_chunk=q_chunk)
        p_sds = abstract_params(model)
        p_ax = model.param_axes()
        b_sds, b_ax = batch_specs(cfg, cell)
        b_sds.pop("labels")
        b_ax.pop("labels")
        in_sh = (
            tree_shardings(mesh, p_sds, p_ax, rules),
            tree_shardings(mesh, b_sds, b_ax, rules),
        )
        return CellRecipe(step, (p_sds, b_sds), in_sh, (), "prefill")

    # decode: one new token against a cache of seq_len
    step = make_decode_step(model)
    p_sds = abstract_params(model)
    p_ax = model.param_axes()
    c_sds = abstract_cache(model, B, S)
    c_ax = model.cache_axes()
    t_sds = SDS((B, 1), jnp.int32)
    t_ax = Axes("batch", None)
    in_sh = (
        tree_shardings(mesh, p_sds, p_ax, rules),
        tree_shardings(mesh, c_sds, c_ax, rules),
        tree_shardings(mesh, {"t": t_sds}, {"t": t_ax}, rules)["t"],
    )
    return CellRecipe(step, (p_sds, c_sds, t_sds), in_sh, (1,), "decode")
