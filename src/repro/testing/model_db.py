"""Model-based differential testing for the MVCC surface.

:class:`ModelDB` is the executable specification: a dict of per-key version
lists plus a range-tombstone list, ~80 lines with no trees, files, or
threads — obviously correct by inspection. The engine under test must agree
with it at EVERY read point: latest reads, pinned snapshots, forward
cursors, reverse cursors, and checkpoint copies.

:func:`run_differential` drives both through the same randomized op stream
(puts straddling the separation threshold, deletes, range deletes, atomic
batches, snapshots taken/released, flushes, compactions, GC passes, crash
reopens, checkpoints) and cross-checks after every op — so a divergence
pinpoints the op sequence that caused it, not just "some state was wrong
at the end". Plain ``random`` only: the driver runs in CI and in the
hypothesis-free local container alike (``tests/test_mvcc.py`` layers
hypothesis's stateful shrinking on top where the dependency exists).

Run standalone::

    PYTHONPATH=src python -m repro.testing.model_db --examples 500
"""
from __future__ import annotations

import argparse
import bisect
import os
import random
import shutil
import tempfile
import time

from repro.core import DB, DBConfig, ShardedDB, WriteBatch

LATEST = (1 << 56) - 1  # MAX_SEQ: the "no snapshot" read point


class ModelDB:
    """Dict-of-versions reference model.

    Sequence numbers are the model's own op counter — they need not equal
    the engine's internal sequences (GC rewrites consume engine seqs the
    model never sees); a comparison only ever pairs an engine read point
    (``None`` or a ``Snapshot``) with the model read point captured at the
    same instant, and visible state is what must match."""

    def __init__(self) -> None:
        self.seq = 0
        # key -> [(seq, value-or-None)] appended in seq order (None = delete)
        self.versions: dict[bytes, list[tuple[int, bytes | None]]] = {}
        self.range_tombs: list[tuple[int, bytes, bytes]] = []

    # -- writes (each returns the op's model seq) ------------------------
    def put(self, key: bytes, value: bytes) -> int:
        self.seq += 1
        self.versions.setdefault(key, []).append((self.seq, value))
        return self.seq

    def delete(self, key: bytes) -> int:
        self.seq += 1
        self.versions.setdefault(key, []).append((self.seq, None))
        return self.seq

    def delete_range(self, start: bytes, end: bytes) -> int:
        self.seq += 1
        self.range_tombs.append((self.seq, start, end))
        return self.seq

    def write_batch(self, ops: list[tuple[str, bytes, bytes]]) -> int:
        """Atomic batch: every op shares ONE seq; within the batch, later
        ops win for point writes, and a range delete does not cover puts
        of the same batch (tombstones cover strictly-older seqs)."""
        self.seq += 1
        for kind, a, b in ops:
            if kind == "put":
                self.versions.setdefault(a, []).append((self.seq, b))
            elif kind == "del":
                self.versions.setdefault(a, []).append((self.seq, None))
            else:  # "delrange"
                self.range_tombs.append((self.seq, a, b))
        # collapse same-seq duplicates per key: later op in the batch wins
        for kind, a, _b in ops:
            if kind in ("put", "del"):
                vs = self.versions[a]
                dups = [i for i, (s, _) in enumerate(vs) if s == self.seq]
                for i in reversed(dups[:-1]):
                    vs.pop(i)
        return self.seq

    def snapshot(self) -> int:
        return self.seq

    # -- reads -----------------------------------------------------------
    def _tomb_seq(self, key: bytes, read_seq: int) -> int:
        best = 0
        for seq, start, end in self.range_tombs:
            if seq <= read_seq and start <= key < end and seq > best:
                best = seq
        return best

    def get(self, key: bytes, read_seq: int = LATEST) -> bytes | None:
        hit = None
        for seq, value in reversed(self.versions.get(key, ())):
            if seq <= read_seq:
                hit = (seq, value)
                break
        if hit is None or hit[1] is None or hit[0] < self._tomb_seq(key, read_seq):
            return None
        return hit[1]

    def items_at(self, read_seq: int = LATEST) -> list[tuple[bytes, bytes]]:
        out = []
        for key in sorted(self.versions):
            v = self.get(key, read_seq)
            if v is not None:
                out.append((key, v))
        return out

    def scan(
        self, start: bytes, count: int, read_seq: int = LATEST
    ) -> list[tuple[bytes, bytes]]:
        items = [kv for kv in self.items_at(read_seq) if kv[0] >= start]
        return items[:count]

    def prev_key(self, bound: bytes | None, read_seq: int = LATEST):
        """Largest visible key strictly below ``bound`` (None = unbounded),
        with its value — the reverse-cursor step."""
        keys = [k for k, _ in self.items_at(read_seq)]
        i = len(keys) if bound is None else bisect.bisect_left(keys, bound)
        if i == 0:
            return None
        k = keys[i - 1]
        return k, self.get(k, read_seq)


# ---------------------------------------------------------------------------
# differential driver
# ---------------------------------------------------------------------------

def _mkcfg(rng: random.Random) -> DBConfig:
    cfg = DBConfig.bvlsm(
        value_threshold=64,
        memtable_size=rng.choice((1024, 4096)),  # tiny: constant flux
        num_bvalue_queues=2,
    )
    cfg.l0_compaction_trigger = 2
    cfg.gc_dead_ratio_trigger = 0.4
    return cfg


def _check_point_reads(db, model, read_pairs, keys, rng, diverge):
    """Compare a sample of gets at every live read point — via single
    ``get`` and via ``multi_get`` (which a ShardedDB fans out per shard),
    so the batched path is differentially checked too."""
    for snap, mseq in read_pairs:
        sample = rng.sample(keys, min(6, len(keys)))
        want = [model.get(k, LATEST if mseq is None else mseq) for k in sample]
        for k, w in zip(sample, want):
            got = db.get(k, snapshot=snap)
            if got != w:
                diverge.append(
                    f"get({k!r}) @ {'latest' if mseq is None else mseq}: "
                    f"model {w!r} != db {got!r}"
                )
        got_many = db.multi_get(sample, snapshot=snap)
        if got_many != want:
            diverge.append(
                f"multi_get({sample!r}) @ {'latest' if mseq is None else mseq}: "
                f"model {want!r} != db {got_many!r}"
            )


def _check_scan(db, model, snap, mseq, start, count, diverge):
    want = model.scan(start, count, LATEST if mseq is None else mseq)
    if snap is None:
        got = list(db.range(start, limit=count))
    else:
        got = []
        with db.iterator(snap) as cur:
            ok = cur.seek(start)
            while ok and len(got) < count:
                got.append((cur.key, cur.value))
                ok = cur.next()
    if got != want:
        diverge.append(
            f"scan({start!r}, {count}) @ {'latest' if mseq is None else mseq}: "
            f"model {[k for k, _ in want]!r} != db {[k for k, _ in got]!r}"
        )


def _check_reverse(db, model, snap, mseq, bound, steps, diverge):
    """Walk ``steps`` reverse-cursor hops from ``bound`` on both sides."""
    rseq = LATEST if mseq is None else mseq
    with db.iterator(snap) as cur:
        if bound is not None:
            # position the cursor: seek lands on first key >= bound
            cur.seek(bound)
        want_bound = cur.key if cur.valid else None
        mb = want_bound
        for _ in range(steps):
            ok = cur.prev()
            want = model.prev_key(mb, rseq)
            if not ok:
                if want is not None:
                    diverge.append(
                        f"prev from {mb!r} @ {rseq}: model {want[0]!r}, db exhausted"
                    )
                return
            if want is None:
                diverge.append(f"prev from {mb!r} @ {rseq}: db {cur.key!r}, model exhausted")
                return
            if (cur.key, cur.value) != want:
                diverge.append(
                    f"prev from {mb!r} @ {rseq}: model {want[0]!r} != db {cur.key!r}"
                )
                return
            mb = cur.key


def run_example(
    seed: int, base_dir: str, n_ops: int = 60, trace=None, shards: int = 0
) -> list[str]:
    """One differential example: fresh DB + model, ``n_ops`` random ops
    with cross-checks after each. Returns divergence strings (empty = ok).
    ``trace`` (a callable taking one string) logs each op as it executes —
    replay a diverging seed with ``trace=print`` to see the exact op
    sequence; it consumes no randomness, so the stream is unchanged.

    ``shards > 0`` runs the same spec against a ``ShardedDB`` of that
    many engines (hash partitioning): every batch then exercises the
    cross-shard commit protocol, every range delete spans shard
    boundaries, and every scan/reverse walk goes through the merged
    cursor — the model doesn't change at all, which is the point."""
    t = trace if trace is not None else (lambda s: None)
    rng = random.Random(seed)
    path = os.path.join(base_dir, f"ex{seed}")

    def _open(p: str):
        if shards > 0:
            return ShardedDB.open(p, shards=shards, config=_mkcfg(rng))
        return DB.open(p, _mkcfg(rng))

    db = _open(path)
    model = ModelDB()
    keys = [f"k{i:03d}".encode() for i in range(rng.randrange(12, 40))]
    # live read points: [(db Snapshot | None, model seq | None)]; the
    # (None, None) pair is the always-present latest read point
    snaps: list[tuple[object, int]] = []
    diverge: list[str] = []

    def val() -> bytes:
        size = rng.choice((8, 8, 24, 80, 300))
        return (f"v{rng.randrange(1 << 28)}_".encode() * 40)[:size]

    try:
        for _op in range(n_ops):
            r = rng.random()
            if r < 0.40:
                k = rng.choice(keys)
                v = val()
                t(f"put {k} {len(v)}B")
                db.put(k, v)
                model.put(k, v)
            elif r < 0.50:
                k = rng.choice(keys)
                t(f"del {k}")
                db.delete(k)
                model.delete(k)
            elif r < 0.60:
                a, b = sorted(rng.sample(keys, 2))
                b = b + b"\x00" if rng.random() < 0.5 else b
                t(f"delrange {a}..{b}")
                db.delete_range(a, b)
                model.delete_range(a, b)
            elif r < 0.68:
                ops = []
                wb = WriteBatch()
                for _ in range(rng.randrange(1, 6)):
                    rr = rng.random()
                    if rr < 0.6:
                        k, v = rng.choice(keys), val()
                        wb.put(k, v)
                        ops.append(("put", k, v))
                    elif rr < 0.8:
                        k = rng.choice(keys)
                        wb.delete(k)
                        ops.append(("del", k, b""))
                    else:
                        a, b = sorted(rng.sample(keys, 2))
                        b = b + b"\x00"
                        wb.delete_range(a, b)
                        ops.append(("delrange", a, b))
                t(f"batch {[(o[0], o[1]) for o in ops]}")
                db.write(wb)
                model.write_batch(ops)
            elif r < 0.74:
                if len(snaps) < 4:
                    snaps.append((db.snapshot(), model.snapshot()))
                    dseq = getattr(snaps[-1][0], "seq", None)
                    if dseq is None:  # ShardedSnapshot: one seq per shard
                        dseq = snaps[-1][0].seqs
                    t(f"snapshot db={dseq} model={snaps[-1][1]}")
                elif snaps:
                    s, _ = snaps.pop(rng.randrange(len(snaps)))
                    s.release()
                    t("release")
            elif r < 0.82:
                t("flush")
                db.flush()
            elif r < 0.86:
                t("compact")
                db.compact_all()
            elif r < 0.90:
                t("gc")
                db.gc_collect(threshold=0.3)
            elif r < 0.96:
                # crash-free reopen: snapshots/cursors do not survive it
                for s, _ in snaps:
                    s.release()
                snaps.clear()
                t("reopen")
                db.flush()
                db.close()
                db = _open(path)
            else:
                t("checkpoint")
                ck = os.path.join(base_dir, f"ck{seed}_{_op}")
                db.checkpoint(ck)
                cdb = _open(ck)
                try:
                    got = list(cdb.range())
                    want = model.items_at(LATEST)
                    if got != want:
                        diverge.append(
                            f"checkpoint scan: model {[k for k, _ in want]!r}"
                            f" != ckpt {[k for k, _ in got]!r}"
                        )
                finally:
                    cdb.close()
                    shutil.rmtree(ck, ignore_errors=True)

            read_pairs = [(None, None)] + snaps
            _check_point_reads(db, model, read_pairs, keys, rng, diverge)
            if rng.random() < 0.35:
                snap, mseq = read_pairs[rng.randrange(len(read_pairs))]
                _check_scan(db, model, snap, mseq, rng.choice(keys), 8, diverge)
            if rng.random() < 0.15:
                snap, mseq = read_pairs[rng.randrange(len(read_pairs))]
                _check_reverse(
                    db, model, snap, mseq, rng.choice(keys), 4, diverge
                )
            if diverge:
                diverge.insert(0, f"seed={seed} op={_op}")
                return diverge
        # final full-state comparison at every live read point
        for snap, mseq in [(None, None)] + snaps:
            _check_scan(db, model, snap, mseq, b"", 1 << 20, diverge)
        if diverge:
            diverge.insert(0, f"seed={seed} op=final")
    finally:
        for s, _ in snaps:
            s.release()
        db.close()
        shutil.rmtree(path, ignore_errors=True)
    return diverge


def run_differential(
    examples: int = 500,
    seed: int = 0,
    n_ops: int = 60,
    verbose: bool = False,
    shards: int = 0,
) -> dict:
    base = tempfile.mkdtemp(prefix="mvccdiff_")
    failures: list[list[str]] = []
    t0 = time.monotonic()
    try:
        for i in range(examples):
            d = run_example(seed * 1_000_003 + i, base, n_ops, shards=shards)
            if d:
                failures.append(d)
            if verbose and ((i + 1) % 50 == 0 or d):
                print(f"[{i + 1}/{examples}] divergences={len(failures)}", flush=True)
    finally:
        shutil.rmtree(base, ignore_errors=True)
    return {
        "examples": examples,
        "shards": shards,
        "failures": failures,
        "seconds": round(time.monotonic() - t0, 2),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--examples", type=int, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ops", type=int, default=60)
    ap.add_argument(
        "--shards", type=int, default=0,
        help="run the spec against a ShardedDB of N engines (0 = plain DB)",
    )
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    rep = run_differential(
        args.examples, args.seed, args.ops, args.verbose, shards=args.shards
    )
    print(
        f"{rep['examples']} examples (shards={rep['shards']}), "
        f"{len(rep['failures'])} diverging, {rep['seconds']}s"
    )
    for f in rep["failures"]:
        for line in f:
            print(f"  {line}")
    return 1 if rep["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
