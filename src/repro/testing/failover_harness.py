"""Randomized primary/replica failover harness.

Each iteration builds a primary and a checkpoint-bootstrapped replica on
*separate* :class:`FaultInjectionEnv` instances (two machines sharing only
the replication stream), runs a randomized workload with transport faults
armed (drop / duplicate / reorder / corrupt frames in flight), and then
plays one scenario:

* **converge** — clear the faults, nudge, wait for catch-up (re-bootstrap
  if a retention hole was flagged) and require the two full scans to be
  byte-identical;
* **crash_primary** — arm a crash point on the primary's env (the op set
  includes ``ship``, so the kill can land exactly on the publish→ship
  edge), let the machine die mid-workload, ``drop_unsynced()`` its disk,
  then ``promote()`` the replica and check the failover invariants;
* **crash_promote** — same, but a second crash point on the *replica's*
  env fires during the promotion itself; the replica is then reopened and
  promoted again (promotion must be re-runnable after a torn attempt);
* **crash_replica** — the replica's machine dies mid-apply; it is
  reopened from its own surviving state, re-attached, and must converge
  (re-bootstrapping if the primary pruned WAL it now needs);
* **diverge** — the replica's applied-payload CRC state is tampered with
  (simulating an apply bug or a post-CRC bit flip); the rolling check must
  flag divergence rather than let the fork ride, and a ``rebootstrap()``
  must restore byte-identical convergence.

Checked invariants, every iteration:

* **no acked-sync write lost after failover**: in sync WAL mode every
  ``put``/``delete`` that returned before the primary died reads back
  exactly its acked value on the promoted replica;
* **async failover serves a prefix**: a promoted replica's value for any
  key is *some* state that key actually held — never garbage, never a
  resurrected overwrite;
* **no silent divergence**: whenever both sides are alive and caught up,
  their full scans match — any fork must have raised ``diverged`` /
  ``needs_rebootstrap`` (and re-bootstrapping must then heal it);
* **the promoted replica is writable** and promotion is idempotent.

Run standalone::

    PYTHONPATH=src python -m repro.testing.failover_harness --iters 200
"""
from __future__ import annotations

import argparse
import contextlib
import io
import os
import random
import shutil
import sys
import tempfile
import time

from repro.core import DB, DBConfig, FaultInjectionEnv
from repro.core.replication import attach, bootstrap_replica

#: primary-side crash-point targets — ``ship`` aims the kill at the
#: publish→transport edge (after durability, before/inside the send)
PRIMARY_TARGETS = [
    (("write", "sync", "rename", "unlink", "truncate", "ship"), None),
    (("ship",), None),
    (("write",), "wal_"),
    (("sync",), "wal_"),
    (("write",), "bvalue"),
    (("sync",), "bvalue"),
]

#: replica-side targets — the apply path's own I/O (value mirror pwrite,
#: local WAL append, memtable-flush outputs)
REPLICA_TARGETS = [
    (("write", "sync", "rename", "unlink", "truncate"), None),
    (("write",), "wal_"),
    (("sync",), "wal_"),
    (("write",), "bvalue"),
]

SCENARIOS = ("converge", "crash_primary", "crash_promote", "crash_replica",
             "diverge")


def _mkcfg(wal_mode: str, env: FaultInjectionEnv) -> DBConfig:
    cfg = DBConfig.bvlsm(
        wal_mode=wal_mode,
        value_threshold=64,
        memtable_size=4096,
        num_bvalue_queues=2,
    )
    cfg.env = env
    cfg.bg_error_backoff_ms = 1.0
    cfg.repl_batch_bytes = 4096       # many small frames → more fault edges
    cfg.repl_crc_interval = 16        # frequent divergence checks
    return cfg


def _scan_all(db: DB) -> list:
    return list(db.range())


def _compare_scans(primary: DB, replica: DB, what: str) -> str | None:
    """Full-scan equality check; an exception on either side is itself a
    violation (a converged replica must be fully readable)."""
    try:
        ps = _scan_all(primary)
    except Exception as e:
        return f"primary scan failed ({what}): {type(e).__name__}: {e}"
    try:
        rs = _scan_all(replica)
    except Exception as e:
        return f"replica scan failed ({what}): {type(e).__name__}: {e}"
    if ps != rs:
        return f"silent divergence {what}"
    return None


def _wait_converged(primary: DB, link, timeout: float = 10.0) -> str | None:
    """Drive the replica to the primary's seq, re-bootstrapping if the
    stream flagged a hole. Returns an error string or None."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        link.nudge()
        if link.follower.wait_caught_up(primary._seq, timeout=1.0):
            return None
        if link.follower.needs_rebootstrap or link.follower.diverged:
            try:
                link.rebootstrap()
            except Exception as e:
                return f"rebootstrap failed: {type(e).__name__}: {e}"
    return f"never converged: lag={link.lag}"


def run_iteration(seed: int, wal_mode: str, base_dir: str) -> dict:
    """One replication cycle. Returns a result dict with ``violations``
    (empty list = pass)."""
    rng = random.Random(seed)
    ppath = os.path.join(base_dir, f"p{seed}")
    rpath = os.path.join(base_dir, f"r{seed}")
    penv = FaultInjectionEnv(seed=seed)
    renv = FaultInjectionEnv(seed=seed + 1)
    scenario = SCENARIOS[rng.randrange(len(SCENARIOS))]

    primary = DB(ppath, _mkcfg(wal_mode, penv))
    keys = [f"key{i:03d}".encode() for i in range(rng.randrange(8, 32))]
    acked: dict[bytes, bytes | None] = {}
    history: dict[bytes, set] = {k: {None} for k in keys}

    def workload(db: DB, n: int) -> bool:
        """Run ``n`` random ops; True if the machine died mid-way."""
        for _i in range(n):
            k = keys[rng.randrange(len(keys))]
            try:
                r = rng.random()
                if r < 0.08:
                    db.delete(k)
                    acked[k] = None
                    history[k].add(None)
                elif r < 0.11:
                    a, b = sorted(rng.sample(keys, 2))
                    db.delete_range(a, b)
                    for kk in keys:
                        if a <= kk < b:
                            acked[kk] = None
                            history[kk].add(None)
                elif r < 0.15:
                    db.flush()
                else:
                    size = rng.choice((8, 40, 200, 700))
                    v = (f"s{seed}v{rng.randrange(1 << 30)}_".encode() * 8)[:size]
                    db.put(k, v)
                    acked[k] = v
                    history[k].add(v)
            except Exception:
                return True
        return False

    # seed data so the bootstrap checkpoint is non-trivial
    workload(primary, rng.randrange(20, 80))
    if rng.random() < 0.5:
        primary.flush()

    replica = bootstrap_replica(primary, rpath, cfg=_mkcfg(wal_mode, renv))
    link = attach(primary, replica)

    # transport faults for the streaming phase (never enough to stall
    # forever: catch-up bridges anything the wire loses)
    if rng.random() < 0.7:
        penv.set_transport_faults(
            drop=rng.uniform(0, 0.2),
            duplicate=rng.uniform(0, 0.15),
            reorder=rng.uniform(0, 0.15),
            corrupt=rng.uniform(0, 0.1),
        )

    violations: list[str] = []
    n_ops = rng.randrange(40, 200)

    if scenario in ("crash_primary", "crash_promote"):
        ops, substr = PRIMARY_TARGETS[rng.randrange(len(PRIMARY_TARGETS))]
        penv.set_crash_after(rng.randrange(5, 300), ops=ops, path_substr=substr)
        workload(primary, n_ops)
        try:
            primary.close(crash=True)
        except Exception:
            pass
        penv.drop_unsynced()
        # the machine is dead but its disk survives: the failover catch-up
        # reads the durable WAL from it, so reads must work again
        penv.disarm_crash()
        penv.set_transport_faults()  # wire gone with the machine

        if scenario == "crash_promote":
            # second kill: the promotion itself dies mid-way on the replica
            ops, substr = REPLICA_TARGETS[rng.randrange(len(REPLICA_TARGETS))]
            renv.set_crash_after(rng.randrange(2, 60), ops=ops, path_substr=substr)
            try:
                replica.promote()
            except Exception:
                pass
            try:
                replica.close(crash=True)
            except Exception:
                pass
            renv.drop_unsynced()
            renv.reset()
            try:
                replica = DB(rpath, _mkcfg(wal_mode, renv), role="replica")
            except Exception as e:
                violations.append(
                    f"replica reopen after torn promote failed: "
                    f"{type(e).__name__}: {e}"
                )
                replica = None
            if replica is not None:
                # re-run the failover: a fresh follower re-reads the dead
                # primary's durable WAL from scratch for the final catch-up
                from repro.core.replication import Follower

                replica._follower = Follower(replica, ppath,
                                             primary_env=renv)
                try:
                    replica.promote()
                except Exception as e:
                    violations.append(
                        f"re-promote failed: {type(e).__name__}: {e}"
                    )
        else:
            try:
                replica.promote()
            except Exception as e:
                violations.append(f"promote failed: {type(e).__name__}: {e}")

        if replica is not None and not violations:
            if replica.replication_status()["role"] != "primary":
                violations.append("promoted replica did not flip role")
            for k, want in acked.items():
                try:
                    got = replica.get(k)
                except Exception as e:
                    violations.append(
                        f"get({k!r}) failed: {type(e).__name__}: {e}")
                    continue
                if wal_mode == "sync":
                    if got != want:
                        violations.append(
                            f"lost acked-sync write {k!r}: "
                            f"want {want!r} got {got!r}")
                elif got not in history[k]:
                    violations.append(f"non-prefix value for {k!r}: {got!r}")
            try:
                replica.promote()  # idempotent
                replica.put(b"post-failover-probe", b"ok")
                if replica.get(b"post-failover-probe") != b"ok":
                    violations.append("post-failover write not readable")
            except Exception as e:
                violations.append(
                    f"promoted replica unusable: {type(e).__name__}: {e}")
        if replica is not None:
            with contextlib.suppress(Exception):
                replica.close()

    elif scenario == "crash_replica":
        ops, substr = REPLICA_TARGETS[rng.randrange(len(REPLICA_TARGETS))]
        renv.set_crash_after(rng.randrange(5, 200), ops=ops, path_substr=substr)
        workload(primary, n_ops)
        link.detach()
        try:
            replica.close(crash=True)
        except Exception:
            pass
        renv.drop_unsynced()
        renv.reset()
        penv.set_transport_faults()
        try:
            replica = DB(rpath, _mkcfg(wal_mode, renv), role="replica")
        except Exception as e:
            violations.append(
                f"replica reopen failed: {type(e).__name__}: {e}")
            replica = None
        if replica is not None:
            link = attach(primary, replica)
            workload(primary, rng.randrange(10, 50))
            err = _wait_converged(primary, link)
            if err:
                violations.append(err)
            else:
                replica = link.replica
                err = _compare_scans(primary, replica, "after replica crash")
                if err:
                    violations.append(err)
            with contextlib.suppress(Exception):
                replica.close()
        primary.close()

    elif scenario == "diverge":
        workload(primary, n_ops // 2)
        penv.set_transport_faults()
        err = _wait_converged(primary, link)
        follower = link.follower
        interval = max(1, replica.cfg.repl_crc_interval)
        # poison the CRC fold of a run that has not STARTED yet: the seeds
        # the follower will fold real payloads onto are now wrong, so the
        # digest the primary ships for that run cannot match (an apply bug
        # in effigy — the frame CRC sees nothing)
        target_run = primary._seq // interval + 1
        with follower._lock:
            follower._runs[target_run] = 0x5A5A5A5A
        # push the stream well past the poisoned run so it completes and
        # its digest rides a later frame out
        for i in range(interval * 3):
            primary.put(f"div{i:04d}".encode(), b"d" * 80)
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline and not follower.diverged:
            link.nudge()
            time.sleep(0.02)
        if err is None and not follower.diverged:
            violations.append("tampered CRC fold never flagged divergence")
        if follower.diverged and not follower.needs_rebootstrap:
            violations.append("diverged without needs_rebootstrap")
        try:
            replica = link.rebootstrap()
        except Exception as e:
            violations.append(f"rebootstrap failed: {type(e).__name__}: {e}")
            replica = None
        if replica is not None:
            err = _wait_converged(primary, link)
            if err:
                violations.append(f"post-rebootstrap {err}")
            else:
                err = _compare_scans(primary, replica, "after rebootstrap")
                if err:
                    violations.append(err)
            with contextlib.suppress(Exception):
                replica.close()
        primary.close()

    else:  # converge
        workload(primary, n_ops)
        penv.set_transport_faults()
        err = _wait_converged(primary, link)
        if err:
            violations.append(err)
        else:
            replica = link.replica
            err = _compare_scans(primary, replica, "in steady state")
            if err:
                f = link.follower
                if not (f.diverged or f.needs_rebootstrap):
                    violations.append(err)
            if replica.replication_status().get("lag", 0) != 0:
                violations.append("caught-up replica reports non-zero lag")
        with contextlib.suppress(Exception):
            link.replica.close()
        primary.close()

    for p in (ppath, rpath, rpath + ".rebase"):
        shutil.rmtree(p, ignore_errors=True)
    return {
        "seed": seed,
        "wal_mode": wal_mode,
        "scenario": scenario,
        "acked": len(acked),
        "violations": violations,
    }


def run_failover_loop(
    iters: int = 200,
    seed: int = 0,
    wal_modes: tuple[str, ...] = ("sync", "async"),
    verbose: bool = False,
) -> dict:
    """Run ``iters`` randomized replication/failover cycles; returns an
    aggregate report (``failures`` empty = all invariants held)."""
    base = tempfile.mkdtemp(prefix="failover_")
    failures = []
    by_scenario: dict[str, int] = {}
    t0 = time.monotonic()
    try:
        for i in range(iters):
            mode = wal_modes[i % len(wal_modes)]
            with contextlib.redirect_stderr(io.StringIO()):
                res = run_iteration(seed * 1_000_003 + i, mode, base)
            by_scenario[res["scenario"]] = by_scenario.get(res["scenario"], 0) + 1
            if res["violations"]:
                failures.append(res)
            if verbose and ((i + 1) % 25 == 0 or res["violations"]):
                print(
                    f"[{i + 1}/{iters}] mode={mode} scenario={res['scenario']} "
                    f"violations={len(res['violations'])}",
                    flush=True,
                )
    finally:
        shutil.rmtree(base, ignore_errors=True)
    return {
        "iterations": iters,
        "scenarios": by_scenario,
        "failures": failures,
        "seconds": round(time.monotonic() - t0, 2),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--wal-mode", choices=("sync", "async", "both"), default="both")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    modes = ("sync", "async") if args.wal_mode == "both" else (args.wal_mode,)
    rep = run_failover_loop(args.iters, args.seed, modes, verbose=args.verbose)
    print(
        f"{rep['iterations']} iterations {rep['scenarios']}, "
        f"{len(rep['failures'])} failing, {rep['seconds']}s"
    )
    for f in rep["failures"]:
        print(f"  seed={f['seed']} mode={f['wal_mode']} "
              f"scenario={f['scenario']}:", file=sys.stderr)
        for v in f["violations"]:
            print(f"    {v}", file=sys.stderr)
    return 1 if rep["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
