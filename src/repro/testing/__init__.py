"""Test/validation utilities shipped with the engine (not test-only code:
the crash-loop harness is a user-runnable durability checker)."""
