"""Randomized crash-loop durability harness.

Each iteration builds a small DB on a :class:`FaultInjectionEnv`, runs a
randomized workload (puts / overwrites / deletes / range deletes, values
straddling the separation threshold, occasional flush / GC / checkpoint
kicks so every pipeline stage is live), and arms a **crash point**: after N
env operations — N random, the op set and path filter random too, so the
kill lands on WAL appends, WAL fsyncs, SSTable writes, manifest appends,
BValue pwrites, renames, unlinks and checkpoint hard-links alike — every
further mutating filesystem op raises ``SimulatedCrashError``. The iteration then simulates the machine dying:
``drop_unsynced()`` rewinds every file to its last-fsynced prefix (undoing
overwrites of previously-synced bytes, RocksDB FaultInjectionTestFS style),
and the DB is reopened on the survivor state.

Checked invariants, every iteration:

* **reopen succeeds** — recovery must handle any torn state the crash left;
* **no lost acked writes** (sync WAL): every ``put``/``delete`` that
  returned before the crash reads back exactly its last acked value;
* **no resurrected stale values** (async WAL): a recovered value must be
  *some* prefix state of that key's history — never a value that was
  superseded before an acked later write, and never garbage;
* **the reopened DB is writable** and a full scan completes;
* **acked checkpoints commit atomically**: every ``checkpoint(dir)`` call
  that returned keeps its MANIFEST (the rename is the commit marker), and
  any checkpoint dir holding a MANIFEST — acked or not — opens as a valid
  DB whose full scan completes; a crash between the hard-links and the
  rename leaves a manifest-less dir that is simply not a DB.

Run standalone::

    PYTHONPATH=src python -m repro.testing.crash_harness --iters 200

or from tests via :func:`run_crash_loop`.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import os
import random
import shutil
import sys
import tempfile
import time

from repro.core import DB, DBConfig, FaultInjectionEnv

#: crash-point op filters the fuzzer draws from — each (ops, path_substr)
#: pair aims the kill at one pipeline edge
CRASH_TARGETS = [
    (("write", "sync", "rename", "unlink", "truncate"), None),  # anywhere
    (("write",), "wal_"),        # WAL append
    (("sync",), "wal_"),         # WAL group fsync
    (("write",), ".sst"),        # flush / compaction output
    (("sync",), ".sst"),         # table durability barrier
    (("write",), "MANIFEST"),    # version edit append
    (("sync",), "MANIFEST"),     # manifest commit fsync
    (("write",), "bvalue"),      # value-log pwrite
    (("sync",), "bvalue"),       # value-log fsync
    (("unlink",), None),         # log/file deletion edges
    (("rename",), None),         # atomic-replace edges
    (("link",), None),           # checkpoint hard-link fan-out
    (("rename",), "MANIFEST"),   # checkpoint commit: MANIFEST.tmp → MANIFEST
    (("write", "sync"), "_ck"),  # anything inside a checkpoint target dir
]


def _mkcfg(wal_mode: str, env: FaultInjectionEnv) -> DBConfig:
    cfg = DBConfig.bvlsm(
        wal_mode=wal_mode,
        value_threshold=64,
        memtable_size=4096,  # tiny: every iteration exercises rotation+flush
        num_bvalue_queues=2,
    )
    cfg.env = env
    cfg.bg_error_backoff_ms = 1.0  # crashing jobs shouldn't sleep long
    cfg.gc_dead_ratio_trigger = 0.3
    return cfg


def run_iteration(seed: int, wal_mode: str, base_dir: str) -> dict:
    """One crash/recover/check cycle. Returns a result dict with
    ``violations`` (list of strings, empty = pass)."""
    rng = random.Random(seed)
    path = os.path.join(base_dir, f"it{seed}")
    env = FaultInjectionEnv(seed=seed)
    db = DB(path, _mkcfg(wal_mode, env))

    keys = [f"key{i:03d}".encode() for i in range(rng.randrange(8, 48))]
    # acked[k]: last value whose put/delete RETURNED before the crash
    # history[k]: every state k ever held (for the async-WAL prefix check)
    acked: dict[bytes, bytes | None] = {}
    history: dict[bytes, set] = {k: {None} for k in keys}
    # checkpoint dirs whose checkpoint() call RETURNED before the crash —
    # each must reopen as a valid read-only DB after the crash
    acked_ckpts: list[str] = []
    attempted_ckpts: list[str] = []

    ops, substr = CRASH_TARGETS[rng.randrange(len(CRASH_TARGETS))]
    env.set_crash_after(rng.randrange(5, 400), ops=ops, path_substr=substr)

    crashed = False
    n_ops = rng.randrange(50, 500)
    for _i in range(n_ops):
        k = keys[rng.randrange(len(keys))]
        try:
            r = rng.random()
            if r < 0.08:
                db.delete(k)
                acked[k] = None
                history[k].add(None)
            elif r < 0.12:
                a, b = sorted(rng.sample(keys, 2))
                b = b + b"\x00" if rng.random() < 0.5 else b
                db.delete_range(a, b)
                for kk in keys:
                    if a <= kk < b:
                        acked[kk] = None
                        history[kk].add(None)
            elif r < 0.16:
                db.flush()
                continue
            elif r < 0.17:
                db.gc_collect(threshold=0.2)
                continue
            elif r < 0.19:
                ck = os.path.join(base_dir, f"it{seed}_ck{_i}")
                attempted_ckpts.append(ck)
                db.checkpoint(ck)
                acked_ckpts.append(ck)
                continue
            else:
                # mix of inline and separated (>= threshold) values
                size = rng.choice((8, 8, 40, 200, 700))
                v = (f"s{seed}v{rng.randrange(1 << 30)}_".encode() * 8)[:size]
                db.put(k, v)
                acked[k] = v
                history[k].add(v)
        except Exception:
            crashed = True
            break
    # the machine dies here (whether or not the armed point fired): no
    # orderly shutdown, unsynced state is gone
    try:
        db.close(crash=True)
    except Exception:
        pass
    env.drop_unsynced()
    env.disarm_crash()
    env.clear_faults()
    env.reset_tracking()

    violations: list[str] = []
    db2 = None
    try:
        db2 = DB(path, _mkcfg(wal_mode, env))
    except Exception as e:
        violations.append(f"reopen failed: {type(e).__name__}: {e}")
    if db2 is not None:
        for k, want in acked.items():
            try:
                got = db2.get(k)
            except Exception as e:
                violations.append(f"get({k!r}) failed: {type(e).__name__}: {e}")
                continue
            if wal_mode == "sync":
                if got != want:
                    violations.append(
                        f"lost acked write {k!r}: want {want!r} got {got!r}"
                    )
            else:
                # async WAL: acked ≠ durable; any prefix state is legal,
                # anything NOT in the history is corruption/resurrection
                if got not in history[k]:
                    violations.append(
                        f"non-prefix value for {k!r}: got {got!r}"
                    )
        try:
            list(db2.range())
            db2.put(b"post-crash-probe", b"ok")
            if db2.get(b"post-crash-probe") != b"ok":
                violations.append("post-recovery write not readable")
            db2.close()
        except Exception as e:
            violations.append(f"post-recovery use failed: {type(e).__name__}: {e}")
    # every checkpoint whose call RETURNED must open as a valid DB: the
    # MANIFEST rename is the commit marker, and everything it references
    # was hard-linked from fsynced files before the rename
    for ck in attempted_ckpts:
        committed = os.path.exists(os.path.join(ck, "MANIFEST"))
        if ck in acked_ckpts and not committed:
            violations.append(f"acked checkpoint lost its MANIFEST: {ck}")
        if committed:
            try:
                cdb = DB(ck, _mkcfg(wal_mode, env))
                list(cdb.range())
                cdb.close()
            except Exception as e:
                violations.append(
                    f"checkpoint {os.path.basename(ck)} does not open clean: "
                    f"{type(e).__name__}: {e}"
                )
        shutil.rmtree(ck, ignore_errors=True)
    shutil.rmtree(path, ignore_errors=True)
    return {
        "seed": seed,
        "wal_mode": wal_mode,
        "crashed_mid_workload": crashed,
        "acked": len(acked),
        "checkpoints": len(acked_ckpts),
        "violations": violations,
    }


def run_crash_loop(
    iters: int = 200,
    seed: int = 0,
    wal_modes: tuple[str, ...] = ("sync", "async"),
    verbose: bool = False,
) -> dict:
    """Run ``iters`` randomized crash cycles; returns an aggregate report
    (``failures`` empty = all invariants held)."""
    base = tempfile.mkdtemp(prefix="crashloop_")
    failures = []
    crashed_mid = 0
    t0 = time.monotonic()
    try:
        for i in range(iters):
            mode = wal_modes[i % len(wal_modes)]
            # worker-thread tracebacks from simulated crashes are expected
            # noise — keep the harness output to the verdict
            with contextlib.redirect_stderr(io.StringIO()):
                res = run_iteration(seed * 1_000_003 + i, mode, base)
            crashed_mid += res["crashed_mid_workload"]
            if res["violations"]:
                failures.append(res)
            if verbose and ((i + 1) % 25 == 0 or res["violations"]):
                print(
                    f"[{i + 1}/{iters}] mode={mode} acked={res['acked']} "
                    f"violations={len(res['violations'])}",
                    flush=True,
                )
    finally:
        shutil.rmtree(base, ignore_errors=True)
    return {
        "iterations": iters,
        "crashed_mid_workload": crashed_mid,
        "failures": failures,
        "seconds": round(time.monotonic() - t0, 2),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--wal-mode", choices=("sync", "async", "both"), default="both")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    modes = ("sync", "async") if args.wal_mode == "both" else (args.wal_mode,)
    rep = run_crash_loop(args.iters, args.seed, modes, verbose=args.verbose)
    print(
        f"{rep['iterations']} iterations, {rep['crashed_mid_workload']} crashed "
        f"mid-workload, {len(rep['failures'])} failing, {rep['seconds']}s"
    )
    for f in rep["failures"]:
        print(f"  seed={f['seed']} mode={f['wal_mode']}:", file=sys.stderr)
        for v in f["violations"]:
            print(f"    {v}", file=sys.stderr)
    return 1 if rep["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
