"""Flash attention (fwd) Pallas TPU kernel.

Tiling: grid = (batch, kv_head, q_blocks); each program streams KV blocks of
one (batch, kv-head) through VMEM while keeping a (block_q · G, head_dim)
query tile and fp32 running (max, sum, acc) in VMEM — the classic online
softmax. GQA is handled by folding the G = H/K query heads of a kv head
into the q-tile's row dimension, which keeps the MXU matmuls dense
(rows = block_q·G ≥ 128 for the assigned configs).

Block sizes are multiples of 128 (MXU lane alignment); the VMEM footprint
per program is
    q_tile (bq·G·hd) + 2·kv_block (bk·hd) + acc (bq·G·hd) + stats,
≈ 1.3 MiB at bq=bk=512, hd=128 — comfortably under the ~16 MiB/core budget.

TPU is the TARGET; correctness is validated in interpret mode on CPU
against ``ref.mha_reference`` (tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq*G, hd)
    k_ref,  # (1, 1, bk, hd)
    v_ref,  # (1, 1, bk, hd)
    o_ref,  # (1, 1, bq*G, hd)
    m_scr,  # (bq*G, 1) fp32
    l_scr,  # (bq*G, 1) fp32
    acc_scr,  # (bq*G, hd) fp32
    *,
    block_q: int,
    block_k: int,
    groups: int,
    sm_scale: float,
    causal: bool,
    window: int | None,
    kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    run = True
    if causal:
        # skip fully-masked kv blocks (rows attend only to keys ≤ their pos)
        run = k_start <= q_start + block_q - 1

    def body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq*G, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq*G, bk)

        # row/col positions: row r belongs to query position q_start + r//G
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // groups
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < kv_len
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq*G, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        # explicit zero for masked lanes: if an entire block is masked,
        # s - m_new would be 0 - 0 and exp() must not resurrect it
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (bq*G, bk)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        pl.when(run)(body)
    else:
        body()

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, T, H, hd)
    k: jax.Array,  # (B, S, K, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    sm_scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, T)
    block_k = min(block_k, S)
    Tp = -(-T // block_q) * block_q
    Sp = -(-S // block_k) * block_k
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))

    # (B, K, T*G, hd): fold each kv-head's query group into rows
    qf = q.reshape(B, Tp, K, G, hd).transpose(0, 2, 1, 3, 4).reshape(B, K, Tp * G, hd)
    kf = k.transpose(0, 2, 1, 3)  # (B, K, Sp, hd)
    vf = v.transpose(0, 2, 1, 3)

    grid = (B, K, Tp // block_q, Sp // block_k)
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        groups=G,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        kv_len=S,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q * G, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q * G, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, Tp * G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(B, K, Tp, G, hd).transpose(0, 2, 1, 3, 4).reshape(B, Tp, H, hd)
    return out[:, :T]
