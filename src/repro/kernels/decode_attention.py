"""Paged flash-decode Pallas TPU kernel — the BVLSM read path on TPU.

This is the paper's pointer-dereference read path mapped onto the TPU
memory hierarchy (DESIGN.md §3): the per-sequence **page table** is the
lightweight Key→ValueOffset metadata (kept in SMEM via scalar prefetch);
the **KV pages** are the big values living in a paged HBM arena; each grid
step dereferences one page id and DMAs that page into VMEM, accumulating
online-softmax partials — never materializing the gathered cache.

Grid = (batch, kv_head, num_pages). BlockSpec index maps use the prefetched
page table to pick the HBM page per step (Pallas TPU's scalar-prefetch
mechanism), so the gather happens in the DMA engine, not as an XLA gather.

Validated in interpret mode against ``ref.paged_decode_reference``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    page_table_ref,  # scalar prefetch: (B, maxp) int32
    lengths_ref,  # scalar prefetch: (B,) int32
    q_ref,  # (1, 1, G, hd)      — this (batch, kv head)'s query group
    k_ref,  # (1, page, hd)      — the dereferenced page
    v_ref,  # (1, page, hd)
    o_ref,  # (1, 1, G, hd)
    m_scr,  # (G, 1) f32
    l_scr,  # (G, 1) f32
    acc_scr,  # (G, hd) f32
    *,
    page_size: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    page_start = pi * page_size

    @pl.when(page_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (G, hd)
        k = k_ref[0].astype(jnp.float32)  # (page, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, page)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(pi == pl.num_programs(2) - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,  # (B, H, hd)
    pages_k: jax.Array,  # (P, page, K, hd) — the paged KV arena
    pages_v: jax.Array,
    page_table: jax.Array,  # (B, maxp) int32 page ids per sequence
    lengths: jax.Array,  # (B,) int32 valid token count per sequence
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    P, page, K, _ = pages_k.shape
    maxp = page_table.shape[1]
    G = H // K
    sm_scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B, K, G, hd)
    # (P, K, page, hd) so one (page id, kv head) indexes a (page, hd) block
    kf = pages_k.transpose(0, 2, 1, 3).reshape(P * K, page, hd)
    vf = pages_v.transpose(0, 2, 1, 3).reshape(P * K, page, hd)

    grid = (B, K, maxp)

    def kv_index(b, h, pi, page_table_ref, lengths_ref):
        pid = page_table_ref[b, pi]
        return (pid * K + h, 0, 0)

    kernel = functools.partial(_decode_kernel, page_size=page, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, pi, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, page, hd), kv_index),
                pl.BlockSpec((1, page, hd), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, pi, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qf, kf, vf)

    return out.reshape(B, H, hd)
