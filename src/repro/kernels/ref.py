"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode sweeps in tests/test_kernels.py assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(q, k, v, *, causal=True, window=None):
    """q (B,T,H,hd); k/v (B,S,K,hd) — exact softmax attention in fp32."""
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(T)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, hd).astype(q.dtype)


def paged_decode_reference(q, pages_k, pages_v, page_table, lengths):
    """q (B,H,hd); pages_* (P, page, K, hd); page_table (B, maxp) int32;
    lengths (B,) int32 — exact paged decode attention."""
    B, H, hd = q.shape
    P, page, K, _ = pages_k.shape
    maxp = page_table.shape[1]
    G = H // K
    # gather each sequence's pages: (B, maxp, page, K, hd) → (B, maxp*page, K, hd)
    kg = pages_k[page_table].reshape(B, maxp * page, K, hd)
    vg = pages_v[page_table].reshape(B, maxp * page, K, hd)
    qg = q.reshape(B, K, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kg.astype(jnp.float32))
    valid = jnp.arange(maxp * page)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vg.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def ssd_chunk_reference(x, dA, B_, C_):
    """Sequential SSD oracle. x (b,t,h,p); dA (b,t,h) log decay;
    B_/C_ (b,t,g,n). Returns (y, final_state (b,h,p,n))."""
    b, t, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    hpg = h // g
    st = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    xg = x.astype(jnp.float32)
    for i in range(t):
        dec = jnp.exp(dA[:, i].astype(jnp.float32))  # (b,h)
        Bx = jnp.einsum(
            "bgn,bghp->bghpn",
            B_[:, i].astype(jnp.float32),
            xg[:, i].reshape(b, g, hpg, p),
        ).reshape(b, h, p, n)
        st = st * dec[:, :, None, None] + Bx
        y = jnp.einsum(
            "bgn,bghpn->bghp", C_[:, i].astype(jnp.float32), st.reshape(b, g, hpg, p, n)
        )
        ys.append(y.reshape(b, h, p))
    return jnp.stack(ys, axis=1).astype(x.dtype), st


def rglru_reference(x, r, i, lam, h0=None):
    """Sequential RG-LRU oracle. x/r/i (B,T,W); lam (W,)."""
    Bb, T, W = x.shape
    c = 8.0
    log_a_base = -c * jax.nn.softplus(lam.astype(jnp.float32))
    h = jnp.zeros((Bb, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(T):
        log_a = r[:, t].astype(jnp.float32) * log_a_base
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = a * h + beta * (i[:, t].astype(jnp.float32) * x[:, t].astype(jnp.float32))
        ys.append(h)
    return jnp.stack(ys, axis=1).astype(x.dtype), h
