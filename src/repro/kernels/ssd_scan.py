"""Mamba-2 SSD chunk kernels (Pallas TPU).

The SSD computation splits into (i) chunk-local quadratic work — MXU
matmuls — and (ii) a tiny inter-chunk state recurrence. The kernels here
implement (i) in two phases around the host-side scan for (ii):

  phase A (``ssd_chunk_states``): per (batch, chunk, head) computes the
      intra-chunk output  y_diag = (CBᵀ ⊙ L) x  and the chunk state
      S = (B ⊙ decay)ᵀ x — three (cs × cs/n) MXU matmuls per program.
  host: inter-chunk scan over  H_c = exp(ΣA_c)·H_{c-1} + S_c  (nc steps of
      an (h, p, n) elementwise update — negligible FLOPs, stays in jnp).
  phase B (``ssd_chunk_output``): y = y_diag + (C ⊙ exp(cumA)) H_inᵀ.

VMEM per program ≈ cs·(p + 2n + cs) fp32 ≈ 0.7 MiB at cs=256, p=64, n=128.
Validated in interpret mode against ``ref.ssd_chunk_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _states_kernel(x_ref, dA_ref, b_ref, c_ref, y_ref, s_ref, *, chunk: int):
    # x (1,1,1,cs,p); dA (1,1,1,cs); b/c (1,1,cs,n); y (1,1,1,cs,p); s (1,1,1,p,n)
    x = x_ref[0, 0, 0].astype(jnp.float32)  # (cs, p)
    dA = dA_ref[0, 0, 0].astype(jnp.float32)  # (cs,)
    B = b_ref[0, 0].astype(jnp.float32)  # (cs, n)
    C = c_ref[0, 0].astype(jnp.float32)  # (cs, n)

    cum = jnp.cumsum(dA)  # (cs,)
    seg = cum[:, None] - cum[None, :]  # (i, j)
    ii = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.exp(jnp.where(ii >= jj, seg, NEG_INF))

    CB = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (i, j)
    scores = CB * L
    y_ref[0, 0, 0, ...] = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    decay = jnp.exp(cum[-1] - cum)  # (cs,)
    Bd = B * decay[:, None]  # (cs, n)
    s_ref[0, 0, 0, ...] = jax.lax.dot_general(
        x, Bd, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(s_ref.dtype)  # (p, n)


def _output_kernel(ydiag_ref, dA_ref, c_ref, hin_ref, y_ref):
    ydiag = ydiag_ref[0, 0, 0].astype(jnp.float32)  # (cs, p)
    dA = dA_ref[0, 0, 0].astype(jnp.float32)  # (cs,)
    C = c_ref[0, 0].astype(jnp.float32)  # (cs, n)
    Hin = hin_ref[0, 0, 0].astype(jnp.float32)  # (p, n)
    cum = jnp.cumsum(dA)
    Cd = C * jnp.exp(cum)[:, None]  # (cs, n)
    y_off = jax.lax.dot_general(
        Cd, Hin, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cs, p)
    y_ref[0, 0, 0, ...] = (ydiag + y_off).astype(y_ref.dtype)


def ssd_chunked_pallas(x, dA, B_, C_, chunk: int, *, interpret: bool = False):
    """x (b,t,h,p); dA (b,t,h); B_/C_ (b,t,g,n) with g=1.
    Returns (y (b,t,h,p), final_state (b,h,p,n))."""
    b, t, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    assert g == 1, "kernel specialization: mamba2 configs use a single group"
    assert t % chunk == 0
    nc = t // chunk

    xc = x.reshape(b, nc, chunk, h, p).transpose(0, 1, 3, 2, 4)  # (b,nc,h,cs,p)
    dAc = dA.reshape(b, nc, chunk, h).transpose(0, 1, 3, 2)  # (b,nc,h,cs)
    Bc = B_.reshape(b, nc, chunk, n)  # (b,nc,cs,n)
    Cc = C_.reshape(b, nc, chunk, n)

    grid = (b, nc, h)
    y_diag, states = pl.pallas_call(
        functools.partial(_states_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda i, c, j: (i, c, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda i, c, j: (i, c, j, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, c, j: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, c, j: (i, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda i, c, j: (i, c, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda i, c, j: (i, c, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, h, chunk, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dAc, Bc, Cc)

    # inter-chunk recurrence (tiny): H_{c} entering chunk c
    chunk_decay = jnp.exp(dAc.astype(jnp.float32).sum(axis=3))  # (b,nc,h)

    def step(H, inp):
        S_c, dec_c = inp
        return dec_c[..., None, None] * H + S_c, H

    S_sw = jnp.moveaxis(states, 1, 0)
    d_sw = jnp.moveaxis(chunk_decay, 1, 0)
    H_last, H_in = jax.lax.scan(step, jnp.zeros((b, h, p, n), jnp.float32), (S_sw, d_sw))
    H_in = jnp.moveaxis(H_in, 0, 1)  # (b,nc,h,p,n)

    y = pl.pallas_call(
        _output_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda i, c, j: (i, c, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda i, c, j: (i, c, j, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, c, j: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda i, c, j: (i, c, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p), lambda i, c, j: (i, c, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc, h, chunk, p), x.dtype),
        interpret=interpret,
    )(y_diag, dAc, Cc, H_in)

    y = y.transpose(0, 1, 3, 2, 4).reshape(b, t, h, p)
    return y, H_last
