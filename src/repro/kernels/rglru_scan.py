"""RG-LRU linear-recurrence Pallas TPU kernel.

The recurrence h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t) is diagonal —
no MXU work — so the kernel's job is bandwidth shaping: stream (T, W)
activation tiles through VMEM once, carrying the (1, W) state in a VMEM
scratch that persists across the sequential T-block grid dimension.
Grid = (batch, W_blocks, T_blocks); the T dimension is innermost so the
state scratch carries across its steps.

This layer is inherently memory-bound (the roofline table shows it); the
win over the jnp associative scan is avoiding its O(log T) full-tensor
round trips — one HBM pass instead of ~log₂(T).

Validated in interpret mode against ``ref.rglru_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_C = 8.0


def _rglru_kernel(x_ref, r_ref, i_ref, lam_ref, h0_ref, y_ref, hout_ref, h_scr, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)  # (1, wb)

    lam = lam_ref[...].astype(jnp.float32)  # (1, wb)
    log_a_base = -_C * jax.nn.softplus(lam)

    def step(t, h):
        x = x_ref[0, t, :].astype(jnp.float32)[None, :]
        r = r_ref[0, t, :].astype(jnp.float32)[None, :]
        i = i_ref[0, t, :].astype(jnp.float32)[None, :]
        log_a = r * log_a_base
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = a * h + beta * (i * x)
        y_ref[0, t, :] = h[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == pl.num_programs(2) - 1)
    def _finish():
        hout_ref[...] = h.astype(hout_ref.dtype)


def rglru_pallas(x, r, i, lam, h0=None, *, block_t: int = 256, block_w: int = 256, interpret: bool = False):
    """x, r, i: (B, T, W); lam (W,); h0 (B, W) fp32. Returns (y, h_last)."""
    B, T, W = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    block_t = min(block_t, T)
    block_w = min(block_w, W)
    assert T % block_t == 0 and W % block_w == 0, (T, W, block_t, block_w)
    lam2 = lam[None, :]  # (1, W)

    grid = (B, W // block_w, T // block_t)
    y, h_last = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_w), lambda b, w, t: (0, w)),
            pl.BlockSpec((1, block_w), lambda b, w, t: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_w), lambda b, w, t: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), x.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(x, r, i, lam2, h0)
    return y, h_last
