"""Jit'd public wrappers for the Pallas kernels.

``use_pallas`` gates kernel vs pure-jnp oracle paths: the CPU container
(and the 512-device dry-run) uses the jnp path — identical math, identical
FLOPs — while TPU deployments flip the flag. ``interpret`` runs the kernel
body in Python on CPU (used by the test sweeps).
"""
from __future__ import annotations

from functools import partial

import jax

from . import ref
from .decode_attention import paged_decode_attention
from .flash_attention import flash_attention
from .rglru_scan import rglru_pallas
from .ssd_scan import ssd_chunked_pallas


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas", "interpret"))
def attention(q, k, v, *, causal=True, window=None, use_pallas=False, interpret=False):
    if use_pallas or interpret:
        return flash_attention(q, k, v, causal=causal, window=window, interpret=interpret)
    return ref.mha_reference(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode(q, pages_k, pages_v, page_table, lengths, *, use_pallas=False, interpret=False):
    if use_pallas or interpret:
        return paged_decode_attention(
            q, pages_k, pages_v, page_table, lengths, interpret=interpret
        )
    return ref.paged_decode_reference(q, pages_k, pages_v, page_table, lengths)


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_scan(x, dA, B_, C_, chunk, *, use_pallas=False, interpret=False):
    if use_pallas or interpret:
        return ssd_chunked_pallas(x, dA, B_, C_, chunk, interpret=interpret)
    return ref.ssd_chunk_reference(x, dA, B_, C_)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rglru(x, r, i, lam, h0=None, *, use_pallas=False, interpret=False):
    if use_pallas or interpret:
        return rglru_pallas(x, r, i, lam, h0, interpret=interpret)
    return ref.rglru_reference(x, r, i, lam, h0)
