"""Train/serve step builders + dry-run state utilities.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit(..., donate_argnums=0)``. Params are stored fp32
(master); model code casts to the config compute dtype (bf16) internally.
Optional gradient accumulation scans over microbatches.

``abstract_state``/``state_shardings`` produce ShapeDtypeStruct pytrees +
NamedShardings without allocating — the 104B-param dry-run never touches
device memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.dist import Axes, tree_shardings
from .optimizer import OptimizerConfig, clip_by_global_norm, opt_init, opt_state_axes, opt_update


@dataclass(frozen=True)
class TrainConfig:
    opt: OptimizerConfig = OptimizerConfig()
    accum_steps: int = 1
    remat: bool = True
    q_chunk: int = 2048


def init_state(model, key, opt_cfg: OptimizerConfig):
    params = model.init(key)
    return {
        "params": params,
        "opt": opt_init(opt_cfg, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_axes(model, opt_cfg: OptimizerConfig, params_shape):
    pax = model.param_axes()
    return {
        "params": pax,
        "opt": opt_state_axes(opt_cfg, pax, params_shape["params"] if "params" in params_shape else params_shape),
        "step": Axes(),
    }


def make_train_step(model, train_cfg: TrainConfig):
    opt_cfg = train_cfg.opt

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=train_cfg.remat, q_chunk=train_cfg.q_chunk)

    def compute_grads(params, batch):
        if train_cfg.accum_steps <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        A = train_cfg.accum_steps

        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return (acc, loss_acc + loss), None

        microbatches = jax.tree.map(
            lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch
        )
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(micro, (zero, 0.0), microbatches)
        grads = jax.tree.map(lambda g: g / A, grads)
        loss = loss_sum / A
        return loss, {"loss": loss}, grads

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_opt, lr = opt_update(opt_cfg, grads, state["opt"], state["params"])
        metrics = dict(metrics)
        metrics.update(grad_norm=gnorm, lr=lr)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(model, q_chunk: int = 2048):
    def prefill_step(params, batch):
        extra = {k: v for k, v in batch.items() if k not in ("tokens",)}
        if "enc_embeds" in extra:
            return model.prefill(params, batch["tokens"], extra["enc_embeds"], q_chunk=q_chunk)
        if "vision_embeds" in extra and hasattr(model, "hidden_states"):
            return model.prefill(params, batch["tokens"], extra["vision_embeds"], q_chunk=q_chunk)
        return model.prefill(params, batch["tokens"], q_chunk=q_chunk)

    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step


# ---------------------------------------------------------------------------
# abstract state for dry-runs (no allocation)
# ---------------------------------------------------------------------------

def abstract_params(model, dtype=None):
    sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    if dtype is not None:
        sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), sds)
    return sds


def abstract_state(model, opt_cfg: OptimizerConfig):
    return jax.eval_shape(
        lambda: init_state(model, jax.random.key(0), opt_cfg)
    )


def abstract_cache(model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))
