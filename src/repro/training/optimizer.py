"""Optimizers built from scratch (no optax offline): AdamW and Adafactor,
plus LR schedules and global-norm clipping. States are pytrees matching the
param tree so the logical-axis shardings apply 1:1.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    epsilon1: float = 1e-30


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads, opt_state, params):
    count = opt_state["count"] + 1
    lr = lr_schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1**c
    bc2 = 1 - b2**c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, lr


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — 2D params only)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def adafactor_init(params):
    def init_leaf(p):
        if _factored(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"f": jax.tree.map(init_leaf, params), "count": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, grads, opt_state, params):
    count = opt_state["count"] + 1
    lr = lr_schedule(cfg, count)
    beta2 = 1.0 - count.astype(jnp.float32) ** (-cfg.decay_rate)

    def upd(g, st, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.epsilon1
        if "vr" in st:
            vr = beta2 * st["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :] / jnp.maximum(vr.mean(-1, keepdims=True)[..., None], cfg.epsilon1)
            )
            step = g / jnp.maximum(denom, cfg.epsilon1)
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            step = g / (jnp.sqrt(v) + 1e-12)
            new_st = {"v": v}
        # update clipping (RMS ≤ 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-12)
        step = step / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_st

    is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(upd, grads, opt_state["f"], params, is_leaf=None)
    # out leaves are tuples (p, st)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_f = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"f": new_f, "count": count}, lr


# ---------------------------------------------------------------------------
# dispatch + state axes
# ---------------------------------------------------------------------------

def opt_init(cfg: OptimizerConfig, params):
    return adamw_init(params) if cfg.name == "adamw" else adafactor_init(params)


def opt_update(cfg: OptimizerConfig, grads, opt_state, params):
    if cfg.name == "adamw":
        return adamw_update(cfg, grads, opt_state, params)
    return adafactor_update(cfg, grads, opt_state, params)


def opt_state_axes(cfg: OptimizerConfig, param_axes, params_shape):
    """Logical axes for the optimizer state, mirroring param axes."""
    from repro.dist import Axes

    if cfg.name == "adamw":
        return {
            "m": param_axes,
            "v": param_axes,
            "count": Axes(),
        }

    def leaf_axes(ax, sds):
        if _factored(sds):
            return {"vr": Axes(*ax.t[:-1]), "vc": Axes(*(ax.t[:-2] + ax.t[-1:]))}
        return {"v": ax}

    return {
        "f": jax.tree.map(leaf_axes, param_axes, params_shape),
        "count": Axes(),
    }
