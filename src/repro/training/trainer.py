"""Fault-tolerant training loop.

* BVLSM checkpoint/restart: resume restores params, optimizer, step AND the
  data-pipeline cursor (exact-batch resume — tested in
  tests/test_trainer.py).
* Preemption: SIGTERM triggers an immediate WAL-committed checkpoint and a
  clean 143 exit — at cluster scale this is the TPU maintenance-event hook.
* Straggler mitigation: per-step wall times feed a rolling median; steps
  slower than ``straggler_factor``× median increment a counter and invoke a
  pluggable callback (at scale: re-shard input files away from the slow
  host; here: observable hook + logged event).
* Async checkpointing keeps the loop's exposure to I/O at snapshot cost
  only (the paper's jitter story — measured in benchmarks/stability.py).
"""
from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint.bvstore import BVCheckpointStore
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.dist import mesh_context, tree_shardings
from repro.models import build_model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig, init_state, make_train_step, state_axes


@dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_interval: int = 50
    ckpt_async: bool = True
    keep_last: int = 2
    seed: int = 0
    log_every: int = 10
    straggler_factor: float = 3.0
    train: TrainConfig = field(default_factory=lambda: TrainConfig(opt=OptimizerConfig(warmup_steps=10, total_steps=1000)))


class Trainer:
    def __init__(self, model_cfg, tcfg: TrainerConfig, mesh=None, straggler_cb=None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.model = build_model(model_cfg)
        self.store = BVCheckpointStore(tcfg.ckpt_dir)
        self.ckpt = CheckpointManager(
            self.store, tcfg.ckpt_interval, tcfg.keep_last, tcfg.ckpt_async
        )
        extra = {}
        if model_cfg.family == "vlm":
            extra["vision_embeds"] = ((model_cfg.n_vision_patches, model_cfg.d_model), np.float32)
        if model_cfg.family == "audio":
            extra["enc_embeds"] = ((model_cfg.enc_len, model_cfg.d_model), np.float32)
        self.pipeline = TokenPipeline(
            model_cfg.vocab, tcfg.global_batch, tcfg.seq_len, seed=tcfg.seed, extra_fields=extra
        )
        self.state = None
        self.step_times: list[float] = []
        self.straggler_events = 0
        self.straggler_cb = straggler_cb
        self._preempted = False
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------------
    def _init_or_restore(self):
        latest = self.store.latest_step()
        template = jax.eval_shape(
            lambda: init_state(self.model, jax.random.key(self.tcfg.seed), self.tcfg.train.opt)
        )
        if latest is not None:
            if self.mesh is not None:
                axes = state_axes(self.model, self.tcfg.train.opt, template)
                self.state, meta = self.store.load_distributed(self.mesh, template, axes, latest)
            else:
                self.state, meta = self.store.load(latest, template=template)
                self.state = jax.tree.map(jax.numpy.asarray, self.state)
            self.pipeline.load_state_dict(meta["extra"]["pipeline"])
            return int(meta["step"])
        self.state = init_state(self.model, jax.random.key(self.tcfg.seed), self.tcfg.train.opt)
        return 0

    def _handle_sigterm(self, signum, frame):
        self._preempted = True

    # ------------------------------------------------------------------
    def run(self) -> dict:
        tcfg = self.tcfg
        prev_handler = signal.signal(signal.SIGTERM, self._handle_sigterm)
        step_fn = make_train_step(self.model, tcfg.train)
        try:
            with mesh_context(self.mesh):
                start = self._init_or_restore()
                if self.mesh is not None:
                    axes = state_axes(
                        self.model, self.tcfg.train.opt,
                        jax.eval_shape(lambda: self.state),
                    )
                    sds = jax.eval_shape(lambda: self.state)
                    st_sh = tree_shardings(self.mesh, sds, axes)
                    jitted = jax.jit(step_fn, in_shardings=(st_sh, None), donate_argnums=0)
                else:
                    jitted = jax.jit(step_fn, donate_argnums=0)

                for step in range(start, tcfg.steps):
                    t0 = time.monotonic()
                    batch = self.pipeline.next_batch()
                    batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                    self.state, metrics = jitted(self.state, batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                    dt = time.monotonic() - t0
                    self.step_times.append(dt)
                    self._check_straggler(step, dt)
                    metrics["step_s"] = dt
                    metrics["step"] = step + 1
                    self.metrics_log.append(metrics)
                    if (step + 1) % tcfg.log_every == 0:
                        print(
                            f"step {step+1}: loss={metrics.get('loss', float('nan')):.4f} "
                            f"({dt*1e3:.0f} ms)",
                            flush=True,
                        )
                    self.ckpt.maybe_save(
                        step + 1, self.state, {"pipeline": self.pipeline.state_dict()}
                    )
                    if self._preempted:
                        self.ckpt.save_now(
                            step + 1, self.state, {"pipeline": self.pipeline.state_dict()}
                        )
                        self.ckpt.wait()
                        print(f"preempted at step {step+1}; checkpoint committed", flush=True)
                        return {"status": "preempted", "step": step + 1, "metrics": self.metrics_log}
                self.ckpt.save_now(tcfg.steps, self.state, {"pipeline": self.pipeline.state_dict()})
                self.ckpt.wait()
            return {"status": "done", "step": tcfg.steps, "metrics": self.metrics_log}
        finally:
            signal.signal(signal.SIGTERM, prev_handler)

    def _check_straggler(self, step: int, dt: float) -> None:
        if len(self.step_times) < 8:
            return
        med = statistics.median(self.step_times[-32:])
        if dt > self.tcfg.straggler_factor * med:
            self.straggler_events += 1
            if self.straggler_cb is not None:
                self.straggler_cb(step, dt, med)

    def close(self) -> None:
        self.ckpt.close()
        self.store.close()
