"""Gradient compression for the slow (DCN / pod) axis.

int8 block-quantization with **error feedback**: each step transmits
quantize(g + e) and carries e' = g + e − dequantize(...) locally. This is
the standard EF-SGD construction that keeps convergence guarantees while
cutting cross-pod gradient bytes 4× (fp32→int8).

``compressed_psum`` composes it with a shard_map psum over a named axis;
on the dry-run mesh that axis is ``pod`` (the DCN hop — see the roofline's
wire_dcn term). The quantizer itself is exactly testable on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. Returns (q int8, scale f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return out[:n].reshape(shape).astype(dtype)


def ef_compress_leaf(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale, g.shape)
    new_err = corrected - deq
    return q, scale, new_err


def compressed_psum(grads, err_state, axis_name: str):
    """EF-int8 psum over `axis_name` (call inside shard_map with that axis
    bound — the pod/DCN hop).

    Protocol per leaf: (1) pmax the per-block scales so every pod shares one
    scale (4 B per 256 elems on the wire); (2) quantize the EF-corrected
    gradient to int8 against the shared scale; (3) psum the payload as s16
    (safe up to 258 pods of ±127 accumulation) — 2 B/elem on the DCN instead
    of 4 B fp32; (4) dequantize the sum, carry the local quantization error.
    Semantics: Σᵢ round((gᵢ+eᵢ)/s)·s with exact error feedback.
    """

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        flat = corrected.reshape(-1)
        pad = (-flat.size) % BLOCK
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, BLOCK)
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jnp.where(scale == 0, 1.0, scale)
        scale = jax.lax.pmax(scale, axis_name)  # shared scale across pods
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int16), axis_name)  # 2 B/elem wire
        total = (qsum.astype(jnp.float32) * scale).reshape(-1)
        n = corrected.size
        total = total[:n].reshape(g.shape)
        local_deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(g.shape)
        new_e = corrected - local_deq
        return total, new_e

    out = jax.tree.map(leaf, grads, err_state)
    summed = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return summed, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
