"""BVLSM-backed distributed checkpoint store.

The paper's WAL-time separation, applied to training state (DESIGN.md §2):

* **big values** = tensor shard chunks (4 MiB) → BValue multi-queue
  parallel writers (one queue per file ≙ one writer per host at cluster
  scale, the NVMe-SQ analogue);
* **lightweight metadata** = the manifest record (tree structure, shapes,
  dtypes, logical shard axes, step, data-iterator cursor, RNG) — the
  Key-ValueOffset side, WAL-committed synchronously.

Commit protocol: shard chunks (async, parallel) → BValue flush barrier →
META record (sync WAL) → WAL flush. A checkpoint exists iff its META
record is durable, so a crash mid-write leaves only orphaned (unreferenced,
GC-able) values, never a torn checkpoint. Restore reads the newest META and
re-shards onto whatever mesh the restarted job has (elastic restart).

Incremental mode skips tensors whose content hash matches the previous
step's — LSM levels naturally hold the deltas and compaction consolidates.
"""
from __future__ import annotations

import hashlib
import io
import time

import jax
import msgpack
import numpy as np

from repro.core import DB, DBConfig, KVStore

CHUNK = 4 << 20  # 4 MiB value chunks (page-aligned batches downstream)


def _leaf_paths(tree) -> list[tuple[str, object]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class BVCheckpointStore:
    def __init__(
        self,
        path: str,
        num_queues: int = 4,
        sync_values: bool = False,
        env=None,
        db: KVStore | None = None,
    ):
        """``db`` injects any :class:`~repro.core.api.KVStore` (a ``DB``
        or a ``ShardedDB``) — the store takes ownership (``close()``
        closes it) and ``path``/``num_queues``/``sync_values``/``env``
        are ignored. Default: a fresh single-engine ``DB`` at ``path``."""
        if db is not None:
            self.db = db
            return
        cfg = DBConfig.bvlsm(
            wal_mode="sync",  # metadata commits are synchronous
            value_threshold=4096,
            num_bvalue_queues=num_queues,
            memtable_size=4 << 20,
            bvcache_bytes=16 << 20,
        )
        cfg.sync_flush_io = sync_values
        cfg.env = env  # pluggable filesystem (fault-injection tests)
        self.db = DB.open(path, cfg)

    def _value_barrier(self) -> None:
        """Every async BValue write durable before a META record commits.
        Engine-aware fast path (per-queue flush, no memtable rotation)
        for ``DB``/``ShardedDB``; a generic KVStore pays a full flush."""
        engines = getattr(self.db, "shards", None)
        if engines is None:
            engines = [self.db]
        if all(hasattr(e, "bvalue") for e in engines):
            for e in engines:
                e.bvalue.flush()
        else:
            self.db.flush()

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def save(self, step: int, state, extra_meta: dict | None = None,
             prev_hashes: dict | None = None) -> dict:
        """Returns {path: (content_hash, src_step)} for incremental chaining —
        src_step is where the chunks PHYSICALLY live (chains of reuse keep
        pointing at the original writer)."""
        t0 = time.monotonic()
        leaves = _leaf_paths(state)
        manifest = []
        hashes: dict[str, tuple] = {}
        reused = 0
        for path, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            buf = arr.tobytes()
            h = hashlib.blake2b(buf, digest_size=16).hexdigest()
            entry = {
                "path": path,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "chunks": max(1, -(-len(buf) // CHUNK)),
                "hash": h,
            }
            prev = prev_hashes.get(path) if prev_hashes else None
            if prev is not None and prev[0] == h:
                entry["reuse_step"] = prev[1]  # original writer's step
                hashes[path] = (h, prev[1])
                reused += 1
            else:
                for ci in range(entry["chunks"]):
                    key = self._chunk_key(step, path, ci)
                    self.db.put(key, buf[ci * CHUNK : (ci + 1) * CHUNK])
                hashes[path] = (h, step)
            manifest.append(entry)
        # barrier: every async BValue write durable before META commits
        self._value_barrier()
        meta = {
            "step": step,
            "time": time.time(),
            "manifest": manifest,
            "extra": extra_meta or {},
            "reused_tensors": reused,
        }
        self.db.put(self._meta_key(step), msgpack.packb(meta, use_bin_type=True))
        self.db.flush()
        save_s = time.monotonic() - t0
        meta["save_seconds"] = save_s
        return hashes

    def _chunk_key(self, step: int, path: str, ci: int) -> bytes:
        return f"ckpt/{step:012d}/t{path}/c{ci:05d}".encode()

    def _meta_key(self, step: int) -> bytes:
        return f"meta/{step:012d}".encode()

    # ------------------------------------------------------------------
    # load
    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(
            int(k[5:]) for k, _ in self.db.range(b"meta/", end=b"meta0")
        )

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def load_meta(self, step: int) -> dict:
        raw = self.db.get(self._meta_key(step))
        if raw is None:
            raise KeyError(f"no checkpoint at step {step}")
        return msgpack.unpackb(raw, raw=False)

    def load(self, step: int | None = None, template=None):
        """Returns (state_pytree_of_np, meta). With `template`, the result
        keeps its tree structure; otherwise a {path: array} dict."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise KeyError("no checkpoints")
        meta = self.load_meta(step)
        arrays: dict[str, np.ndarray] = {}
        for ent in meta["manifest"]:
            src_step = ent.get("reuse_step", step)
            parts = []
            for ci in range(ent["chunks"]):
                buf = self.db.get(self._chunk_key(src_step, ent["path"], ci))
                if buf is None:
                    raise IOError(f"missing chunk {ent['path']}#{ci} @ step {src_step}")
                parts.append(buf)
            raw = b"".join(parts)
            arrays[ent["path"]] = np.frombuffer(raw, dtype=ent["dtype"]).reshape(ent["shape"])
        if template is None:
            return arrays, meta
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = [arrays[jax.tree_util.keystr(kp)] for kp, _ in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves), meta

    def load_distributed(self, mesh, template, axes_tree, step: int | None = None):
        """Elastic restore: load host arrays and re-shard onto `mesh`
        (which may differ from the mesh the checkpoint was written on)."""
        from repro.dist import tree_shardings

        state, meta = self.load(step, template=template)
        sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        shardings = tree_shardings(mesh, sds, axes_tree)
        out = jax.tree.map(lambda a, s: jax.device_put(a, s), state, shardings)
        return out, meta

    # ------------------------------------------------------------------
    # retention
    # ------------------------------------------------------------------
    def delete_step(self, step: int) -> None:
        self.load_meta(step)  # raises KeyError if the step doesn't exist
        # one range tombstone covers every chunk the step physically owns
        # (reused chunks live under their writer's prefix, outside this
        # range) — constant WAL traffic instead of one delete per chunk
        prefix = f"ckpt/{step:012d}/".encode()
        self.db.delete_range(prefix, prefix + b"\xff")
        self.db.delete(self._meta_key(step))

    # ------------------------------------------------------------------
    # online backup
    # ------------------------------------------------------------------
    def backup(self, directory: str, base: str | None = None) -> str:
        """Hard-link an online, crash-consistent image of the whole store
        into ``directory`` (``DB.checkpoint``): every committed training
        checkpoint in it, openable as a ``BVCheckpointStore`` — without
        pausing in-flight saves. ``base`` (a previous backup directory)
        makes the image incremental: files already present in the base are
        hard-linked from it instead of from the live store. Returns
        ``directory``."""
        if base is None:
            self.db.checkpoint(directory)
        else:  # incremental images are a single-DB feature
            self.db.checkpoint(directory, base=base)
        return directory

    def stats(self) -> dict:
        return self.db.stats()

    def close(self) -> None:
        self.db.close()
