"""Async checkpoint manager: snapshots device state, hands the write to a
background thread (whose big values flow through the BValue multi-queue
writers), keeps the last N checkpoints, and exposes a preemption hook —
the trainer's SIGTERM handler calls ``save_now`` and the WAL-committed META
record makes the shutdown checkpoint crash-consistent.

The paper's I/O-jitter claim maps here: synchronous checkpointing stalls
the train loop for the full serialization+fsync time; BVLSM-async hides it
(benchmarks/stability.py measures both).
"""
from __future__ import annotations

import threading
import time

import jax

from .bvstore import BVCheckpointStore


class CheckpointManager:
    def __init__(
        self,
        store: BVCheckpointStore,
        interval_steps: int = 100,
        keep_last: int = 3,
        async_save: bool = True,
        incremental: bool = True,
    ):
        self.store = store
        self.interval = interval_steps
        self.keep_last = keep_last
        self.async_save = async_save
        self.incremental = incremental
        self._prev_hashes: dict | None = None
        self._pending: threading.Thread | None = None
        self._lock = threading.Lock()
        self.save_count = 0
        self.stall_seconds = 0.0  # time the TRAIN LOOP was blocked

    def maybe_save(self, step: int, state, extra_meta: dict | None = None) -> bool:
        if step % self.interval != 0:
            return False
        self.save_now(step, state, extra_meta)
        return True

    def save_now(self, step: int, state, extra_meta: dict | None = None) -> None:
        t0 = time.monotonic()
        self.wait()  # one in-flight checkpoint at a time
        host_state = jax.tree.map(lambda x: jax.device_get(x), state)
        snapshot_s = time.monotonic() - t0

        def _write():
            prev = self._prev_hashes if self.incremental else None
            hashes = self.store.save(step, host_state, extra_meta, prev_hashes=prev)
            with self._lock:
                self._prev_hashes = hashes
                self.save_count += 1
            self._retire(step)

        if self.async_save:
            self._pending = threading.Thread(target=_write, name=f"ckpt-{step}", daemon=True)
            self._pending.start()
            self.stall_seconds += snapshot_s  # loop only pays the snapshot
        else:
            _write()
            self.stall_seconds += time.monotonic() - t0

    def _retire(self, newest_step: int) -> None:
        steps = self.store.steps()
        # incremental checkpoints may reference older steps' chunks — only
        # retire steps no live checkpoint reuses
        keep = set(steps[-self.keep_last :])
        referenced = set()
        for s in keep:
            for ent in self.store.load_meta(s)["manifest"]:
                if "reuse_step" in ent:
                    referenced.add(ent["reuse_step"])
        for s in steps[: -self.keep_last]:
            if s not in referenced:
                try:
                    self.store.delete_step(s)
                except KeyError:
                    pass

    def backup(self, directory: str, base: str | None = None) -> str:
        """Durable offline copy of the store (e.g. before a risky restart):
        waits for the in-flight save so the image contains it, then
        hard-links the store into ``directory`` via ``DB.checkpoint``.
        ``base`` points at a previous backup to make this one incremental."""
        self.wait()
        return self.store.backup(directory, base=base)

    def wait(self) -> None:
        if self._pending is not None and self._pending.is_alive():
            t0 = time.monotonic()
            self._pending.join()
            self.stall_seconds += time.monotonic() - t0
        self._pending = None

    def close(self) -> None:
        self.wait()
