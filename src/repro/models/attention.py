"""Grouped-query attention: train/prefill (optionally chunked + windowed) and
single-token decode against a KV cache.

The pure-jnp path here is the dry-run/oracle implementation; the Pallas
flash kernels in :mod:`repro.kernels` are drop-in replacements gated by
``use_pallas`` (see kernels/ops.py).

Shapes: q (B, T, H, D); k/v (B, S, K, D) with H = K·G (GQA groups).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q (B,T,K,G,D), k (B,S,K,D) → (B,K,G,T,S) fp32."""
    return jnp.einsum("btkgd,bskd->bkgts", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p (B,K,G,T,S) (same dtype as v), v (B,S,K,D) → (B,T,K,G,D)."""
    return jnp.einsum("bkgts,bskd->btkgd", p, v)


def _mask(q_pos, k_pos, causal: bool, window: int | None):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 2048,
) -> jax.Array:
    """Exact attention, chunked over query blocks so peak memory is
    O(T·q_chunk) instead of O(T²). q (B,T,H,D) → (B,T,H,D)."""
    B, T, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, K, G, D) * scale

    k_pos = jnp.arange(S)

    def block(args):
        qc, q0 = args  # qc: (B, C, K, G, D); q0: scalar chunk start
        C = qc.shape[1]
        s = _gqa_scores(qc, k)
        q_pos = q0 + jnp.arange(C)
        m = _mask(q_pos, k_pos, causal, window)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return _gqa_out(p, v)

    from repro.dist.perf import perf

    if T <= q_chunk:
        out = block((qg, jnp.array(0)))
    elif causal and perf().causal_chunk_growth:
        # §Perf V4: query chunk i only attends keys [lo, (i+1)·c) — static
        # growing slices halve attention FLOPs vs full-width chunks.
        assert T % q_chunk == 0, (T, q_chunk)
        n = T // q_chunk
        outs = []
        for i in range(n):
            qc = qg[:, i * q_chunk : (i + 1) * q_chunk]
            hi = (i + 1) * q_chunk
            lo = max(0, i * q_chunk - window + 1) if window is not None else 0
            lo = (lo // 128) * 128  # keep slices lane-aligned
            kc, vc = k[:, lo:hi], v[:, lo:hi]
            s = _gqa_scores(qc, kc)
            q_pos = i * q_chunk + jnp.arange(q_chunk)
            m = _mask(q_pos, lo + jnp.arange(hi - lo), causal, window)
            s = jnp.where(m[None, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
            outs.append(_gqa_out(p, vc))
        out = jnp.concatenate(outs, axis=1)
    else:
        assert T % q_chunk == 0, (T, q_chunk)
        n = T // q_chunk
        qs = qg.reshape(B, n, q_chunk, K, G, D).transpose(1, 0, 2, 3, 4, 5)
        starts = jnp.arange(n) * q_chunk
        outs = jax.lax.map(block, (qs, starts))  # (n, B, C, K, G, D)
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, K, G, D)
    return out.reshape(B, T, H, D)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """One-step decode. q (B,1,H,D); caches (B,S,K,D); cache_len () or (B,)
    = number of valid cache entries (the new token's K/V already written).
    With ``window`` the cache is a ring buffer of size S=window and all
    slots are valid once wrapped."""
    B, _, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, K, G, D) * scale
    s = _gqa_scores(qg, k_cache)  # (B,K,G,1,S)
    pos = jnp.arange(S)
    if jnp.ndim(cache_len) == 0:
        valid = pos < cache_len
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    else:
        valid = pos[None, :] < cache_len[:, None]
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)  # fp32 — decode is memory-bound; fp32
    # accumulation is free and matches the sharded flash-decode numerics
    out = jnp.einsum(
        "bkgts,bskd->btkgd", p, v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
    return out.reshape(B, 1, H, D)


def update_cache(cache: jax.Array, new: jax.Array, index: jax.Array, ring: bool = False):
    """cache (B,S,K,D) ← new (B,1,K,D) at position index (ring: index % S)."""
    S = cache.shape[1]
    idx = jnp.mod(index, S) if ring else index
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), idx, axis=1)


# ---------------------------------------------------------------------------
# §Perf V3: flash-decode over the model-axis-sharded KV sequence
# ---------------------------------------------------------------------------

def sharded_decode_update_attend(q, k_cache, v_cache, k_new, v_new, pos):
    """Cache update + decode attention with the cache's SEQ dim sharded over
    `model`, via shard_map: each shard writes its slot (if it owns position
    ``pos``) and computes partial online-softmax stats over its local keys;
    the combine is a psum of (B,H,hd)+(B,H) — ~KB instead of the per-layer
    cache all-gather GSPMD would otherwise emit.

    q (B,1,H,D); caches (B,S,K,D); k_new/v_new (B,1,K,D); pos scalar
    (cache_len = pos + 1). Returns (out (B,1,H,D), k_cache, v_cache).
    """
    from repro.dist import active_mesh, logical_to_spec, shard_map

    mesh = active_mesh()
    B, S, K, D = k_cache.shape
    H = q.shape[2]
    n_shards = mesh.shape.get("model", 1) if mesh is not None else 1
    if mesh is None or n_shards == 1 or S % n_shards:
        kc = update_cache(k_cache, k_new, pos)
        vc = update_cache(v_cache, v_new, pos)
        return decode_attention(q, kc, vc, pos + 1), kc, vc

    from jax.sharding import PartitionSpec as P

    cache_spec = logical_to_spec(("cache_batch", "kv_seq", None, None), k_cache.shape, mesh)
    bspec = cache_spec[0]  # however batch resolved (data / (pod,data) / None)
    q_spec = P(bspec, None, None, None)
    # return attention output with HEADS sharded over model so the
    # downstream row-parallel wo einsum keeps its TP pattern — a replicated
    # output makes GSPMD replicate the whole layer's compute.
    H_l = H // n_shards if H % n_shards == 0 else None
    o_spec = P(bspec, None, "model", None) if H_l else q_spec
    S_l = S // n_shards
    G = H // K
    scale = 1.0 / math.sqrt(D)

    def f(q, kc, vc, kn, vn, pos):
        sid = jax.lax.axis_index("model")
        # --- shard-local cache write ---
        local = pos - sid * S_l
        owner = (local >= 0) & (local < S_l)
        idx = jnp.clip(local, 0, S_l - 1)
        cur_k = jax.lax.dynamic_slice_in_dim(kc, idx, 1, 1)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, idx, 1, 1)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, jnp.where(owner, kn.astype(kc.dtype), cur_k), idx, 1
        )
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, jnp.where(owner, vn.astype(vc.dtype), cur_v), idx, 1
        )
        # --- partial flash stats over local keys ---
        qg = q.reshape(-1, 1, K, G, D) * scale
        s = _gqa_scores(qg, kc)  # (B,K,G,1,S_l) fp32
        kpos = sid * S_l + jnp.arange(S_l)
        valid = kpos < pos + 1
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)  # (B,K,G,1)
        p = jnp.where(valid[None, None, None, None, :], jnp.exp(s - m[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)  # (B,K,G,1)
        # fp32 accumulation (standard flash-decode): partial sums must not
        # round to bf16 before the cross-shard combine
        acc = jnp.einsum(
            "bkgts,bskd->btkgd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )  # (B,1,K,G,D)
        # --- combine across shards (tiny) ---
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr.transpose(0, 3, 1, 2)[..., None], "model")
        l_g = jnp.where(l_g == 0.0, 1.0, l_g)
        out = acc_g / l_g.transpose(0, 3, 1, 2)[..., None]
        out = out.reshape(-1, 1, H, D).astype(q.dtype)
        if H_l:
            out = jax.lax.dynamic_slice_in_dim(out, sid * H_l, H_l, axis=2)
        return out, kc, vc

    manual = {"model"} | (
        {a for a in ("data", "pod") if a in mesh.shape and bspec
         and a in (bspec if isinstance(bspec, tuple) else (bspec,))}
    )
    out, kc, vc = shard_map(
        f,
        mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, q_spec, q_spec, P()),
        out_specs=(o_spec, cache_spec, cache_spec),
        axis_names=frozenset(manual),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, pos)
    return out, kc, vc
