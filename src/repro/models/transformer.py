"""Decoder-only transformer LM covering the dense / moe / vlm families.

One parameterized implementation: GQA attention (+qk-norm for qwen3,
+parallel attn/FFN residual block for command-r, +bias for qwen2-moe),
GLU or GELU FFN, optional MoE FFN, optional vision-embedding merge (vlm,
frontend stubbed per the assignment), learned or rotary positions.

Layers are stacked along a leading ``L`` axis and applied with
``lax.scan`` (keeps HLO size O(1) in depth — essential for the 512-device
dry-run compiles) with optional remat.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.dist import Axes, constrain, constrain_tree
from . import attention as attn_lib
from .common import (
    apply_rope,
    embed_axes,
    embed_tokens,
    glu_activation,
    init_embedding,
    logits_from_hidden,
    norm,
    rope_tables,
    softmax_cross_entropy,
    truncated_normal,
)
from .moe import init_moe, moe_axes, moe_ffn


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attn(key, cfg, L: int):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 7)
    p = {
        "wq": truncated_normal(ks[0], (L, d, H * hd), std=d**-0.5),
        "wk": truncated_normal(ks[1], (L, d, K * hd), std=d**-0.5),
        "wv": truncated_normal(ks[2], (L, d, K * hd), std=d**-0.5),
        "wo": truncated_normal(ks[3], (L, H * hd, d), std=(H * hd) ** -0.5),
    }
    if cfg.attention_bias:
        p["bq"] = jnp.zeros((L, H * hd))
        p["bk"] = jnp.zeros((L, K * hd))
        p["bv"] = jnp.zeros((L, K * hd))
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((L, hd))
        p["k_norm"] = jnp.zeros((L, hd))
    return p


def attn_axes(cfg) -> dict:
    p = {
        "wq": Axes("layers", "param_embed", "heads"),
        "wk": Axes("layers", "param_embed", "kv"),
        "wv": Axes("layers", "param_embed", "kv"),
        "wo": Axes("layers", "heads", "param_embed"),
    }
    if cfg.attention_bias:
        p["bq"] = Axes("layers", "heads")
        p["bk"] = Axes("layers", "kv")
        p["bv"] = Axes("layers", "kv")
    if cfg.qk_norm:
        p["q_norm"] = Axes("layers", None)
        p["k_norm"] = Axes("layers", None)
    return p


def init_mlp(key, cfg, L: int):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "gelu":
        return {
            "w_up": truncated_normal(ks[0], (L, d, ff), std=d**-0.5),
            "w_down": truncated_normal(ks[1], (L, ff, d), std=ff**-0.5),
        }
    return {
        "w_gate": truncated_normal(ks[0], (L, d, ff), std=d**-0.5),
        "w_up": truncated_normal(ks[1], (L, d, ff), std=d**-0.5),
        "w_down": truncated_normal(ks[2], (L, ff, d), std=ff**-0.5),
    }


def mlp_axes(cfg) -> dict:
    if cfg.activation == "gelu":
        return {
            "w_up": Axes("layers", "param_embed", "mlp"),
            "w_down": Axes("layers", "mlp", "param_embed"),
        }
    return {
        "w_gate": Axes("layers", "param_embed", "mlp"),
        "w_up": Axes("layers", "param_embed", "mlp"),
        "w_down": Axes("layers", "mlp", "param_embed"),
    }


def row_parallel_einsum(u: jax.Array, w: jax.Array) -> jax.Array:
    """§Perf V9: u (B,T,F) with F sharded over `model`, w (F,D) row-sharded —
    local matmul + EXPLICIT bf16 psum via shard_map (auto over data axes).
    GSPMD would otherwise all-reduce the f32 partial accumulators."""
    from repro.dist import active_mesh, shard_map
    from repro.dist.perf import perf

    mesh = active_mesh()
    F = u.shape[-1]
    if (
        not perf().bf16_rowparallel
        or mesh is None
        or "model" not in mesh.shape
        or F % mesh.shape["model"]
        # XLA:CPU's AllReducePromotion pass hard-crashes (abort, not raise)
        # on ANY bf16 reduction collective — TPU-only path; the CPU dry-run
        # reports the f32 baseline plus a documented bf16 adjustment.
        or jax.default_backend() == "cpu"
    ):
        return jnp.einsum("btf,fd->btd", u, w)
    from jax.sharding import PartitionSpec as P

    def f(u_l, w_l):
        y = jnp.einsum("btf,fd->btd", u_l, w_l).astype(u.dtype)
        # bf16 reduce-scatter + all-gather (the ring-AR decomposition): same
        # wire as an AR but in 2-byte lanes — and XLA:CPU's AllReducePromotion
        # pass (which hard-crashes on bf16 ARs) never fires.
        y = jax.lax.psum_scatter(y, "model", scatter_dimension=2, tiled=True)
        return jax.lax.all_gather(y, "model", axis=2, tiled=True)

    return shard_map(
        f,
        mesh=mesh,
        in_specs=(P(None, None, "model"), P("model", None)),
        out_specs=P(None, None, None),
        axis_names=frozenset({"model"}),
        check_vma=False,
    )(u, w)


def apply_mlp(lp: dict, h: jax.Array, cfg) -> jax.Array:
    if cfg.activation == "gelu":
        u = jnp.einsum("btd,df->btf", h, lp["w_up"].astype(h.dtype))
        u = constrain(jax.nn.gelu(u, approximate=True), ("batch", "seq", "act_mlp"))
        return row_parallel_einsum(u, lp["w_down"].astype(h.dtype))
    g = jnp.einsum("btd,df->btf", h, lp["w_gate"].astype(h.dtype))
    u = jnp.einsum("btd,df->btf", h, lp["w_up"].astype(h.dtype))
    u = constrain(glu_activation(g, u, cfg.activation), ("batch", "seq", "act_mlp"))
    return row_parallel_einsum(u, lp["w_down"].astype(h.dtype))


def qkv(lp: dict, h: jax.Array, cfg, sin, cos):
    """h (B,T,d) → q (B,T,H,hd), k/v (B,T,K,hd) with rope applied."""
    B, T, _ = h.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("btd,dh->bth", h, lp["wq"].astype(h.dtype))
    k = jnp.einsum("btd,dh->bth", h, lp["wk"].astype(h.dtype))
    v = jnp.einsum("btd,dh->bth", h, lp["wv"].astype(h.dtype))
    if cfg.attention_bias:
        q = q + lp["bq"].astype(h.dtype)
        k = k + lp["bk"].astype(h.dtype)
        v = v + lp["bv"].astype(h.dtype)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, K, hd)
    v = v.reshape(B, T, K, hd)
    if cfg.qk_norm:
        from .common import rmsnorm

        q = rmsnorm(q, lp["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, lp["k_norm"], cfg.rms_eps)
    if cfg.use_rope:
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    q = constrain(q, ("batch", "seq", "act_heads", None))
    k = constrain(k, ("batch", "seq", "act_kv", None))
    v = constrain(v, ("batch", "seq", "act_kv", None))
    return q, k, v


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class TransformerLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- init ----------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        L = cfg.n_layers
        ks = jax.random.split(key, 6)
        p: dict = {
            "embed": init_embedding(ks[0], cfg),
            "ln1": jnp.zeros((L, cfg.d_model)),
            "ln_f": jnp.zeros((cfg.d_model,)),
            "attn": init_attn(ks[1], cfg, L),
        }
        if not cfg.parallel_block:
            p["ln2"] = jnp.zeros((L, cfg.d_model))
        if cfg.family == "moe":
            p["moe"] = init_moe(ks[2], cfg)
        else:
            p["mlp"] = init_mlp(ks[2], cfg, L)
        if not cfg.tie_embeddings:
            p["out_embed"] = init_embedding(ks[3], cfg)
        if cfg.pos_emb == "learned":
            p["pos_embed"] = truncated_normal(ks[4], (8192, cfg.d_model), std=0.02)
        return p

    def param_axes(self) -> dict:
        cfg = self.cfg
        p: dict = {
            "embed": embed_axes(),
            "ln1": Axes("layers", "param_embed"),
            "ln_f": Axes("param_embed"),
            "attn": attn_axes(cfg),
        }
        if not cfg.parallel_block:
            p["ln2"] = Axes("layers", "param_embed")
        if cfg.family == "moe":
            p["moe"] = moe_axes(cfg)
        else:
            p["mlp"] = mlp_axes(cfg)
        if not cfg.tie_embeddings:
            p["out_embed"] = embed_axes()
        if cfg.pos_emb == "learned":
            p["pos_embed"] = Axes("param_seq", "param_embed")
        return p

    # -- layer stacking helpers ------------------------------------------------
    def _stacked_axes(self) -> dict:
        ax = self.param_axes()
        st = {"ln1": ax["ln1"], "attn": ax["attn"]}
        if "ln2" in ax:
            st["ln2"] = ax["ln2"]
        if "moe" in ax:
            st["moe"] = ax["moe"]
        if "mlp" in ax:
            st["mlp"] = ax["mlp"]
        return st

    def _stacked(self, params: dict) -> dict:
        st = {"ln1": params["ln1"], "attn": params["attn"]}
        if "ln2" in params:
            st["ln2"] = params["ln2"]
        if "moe" in params:
            st["moe"] = params["moe"]
        if "mlp" in params:
            st["mlp"] = params["mlp"]
        from repro.dist.perf import perf

        if perf().cast_weights_early:
            # §Perf V6: matmul weights cross the FSDP gather in bf16
            dtype = jnp.dtype(self.cfg.dtype)
            st = jax.tree.map(lambda p: p.astype(dtype) if p.ndim >= 3 else p, st)
        return st

    # -- forward (train / prefill) ----------------------------------------------
    def _layer(self, x, lp, sin, cos, *, collect_kv: bool, q_chunk: int):
        cfg = self.cfg
        h = norm(x, lp["ln1"], cfg.rms_eps, cfg.norm_type)
        q, k, v = qkv(lp["attn"], h, cfg, sin, cos)
        ao = attn_lib.full_attention(q, k, v, causal=True, q_chunk=q_chunk)
        ao = row_parallel_einsum(
            ao.reshape(ao.shape[0], ao.shape[1], -1), lp["attn"]["wo"].astype(x.dtype)
        )
        ao = jax.ad_checkpoint.checkpoint_name(ao, "attn_out")
        aux = jnp.zeros((), jnp.float32)
        if cfg.parallel_block:
            mo = apply_mlp(lp["mlp"], h, cfg)
            x = x + ao + mo
        else:
            x = x + ao
            h2 = norm(x, lp["ln2"], cfg.rms_eps, cfg.norm_type)
            if cfg.family == "moe":
                mo, aux = moe_ffn(lp["moe"], h2, cfg)
            else:
                mo = apply_mlp(lp["mlp"], h2, cfg)
            mo = jax.ad_checkpoint.checkpoint_name(mo, "mlp_out")
            x = x + mo
        x = constrain(x, ("batch", "seq", "embed"))
        kv = (k, v) if collect_kv else (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
        return x, aux, kv

    def hidden_states(
        self,
        params: dict,
        tokens: jax.Array,
        vision_embeds: jax.Array | None = None,
        *,
        remat: bool = False,
        collect_kv: bool = False,
        q_chunk: int = 2048,
    ):
        """Returns (hidden (B,T,d), aux_loss, stacked_kv or None)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        B, T = tokens.shape
        x = embed_tokens(params["embed"], tokens, dtype)
        if vision_embeds is not None:
            P = vision_embeds.shape[1]
            x = jax.lax.dynamic_update_slice(x, vision_embeds.astype(dtype), (0, 0, 0))
        if cfg.pos_emb == "learned":
            x = x + params["pos_embed"][:T].astype(dtype)
        sin, cos = rope_tables(jnp.arange(T), cfg.resolved_head_dim, cfg.rope_theta)

        body = partial(self._layer, collect_kv=collect_kv, q_chunk=q_chunk)
        if remat:
            from repro.dist.perf import perf

            # §Perf V1: saving the two post-all-reduce tensors per layer
            # keeps the backward from re-running the TP collectives.
            policy = (
                jax.checkpoint_policies.save_only_these_names("attn_out", "mlp_out")
                if perf().save_dot_outputs
                else jax.checkpoint_policies.nothing_saveable
            )
            body = jax.checkpoint(body, policy=policy)

        stacked_axes = self._stacked_axes()

        def scan_fn(carry, lp):
            x, aux = carry
            lp = constrain_tree(lp, stacked_axes, drop_leading=1)
            x, aux_l, kv = body(x, lp, sin, cos)
            return (x, aux + aux_l), kv

        (x, aux), kvs = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)), self._stacked(params))
        x = norm(x, params["ln_f"], cfg.rms_eps, cfg.norm_type)
        return x, aux, (kvs if collect_kv else None)

    def forward(self, params, tokens, vision_embeds=None, **kw):
        x, aux, _ = self.hidden_states(params, tokens, vision_embeds, **kw)
        out_emb = params["embed"] if self.cfg.tie_embeddings else params["out_embed"]
        return logits_from_hidden(x, out_emb, self.cfg.vocab), aux

    def loss(self, params, batch, *, remat: bool = True, q_chunk: int = 2048):
        logits, aux = self.forward(
            params,
            batch["tokens"],
            batch.get("vision_embeds"),
            remat=remat,
            q_chunk=q_chunk,
        )
        loss, metrics = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
        if self.cfg.family == "moe":
            loss = loss + self.cfg.router_aux_coef * aux
            metrics["aux_loss"] = aux
        metrics["loss"] = loss
        return loss, metrics

    # -- serving ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        K, hd, L = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_layers
        shape = (L, batch, max_len, K, hd)
        return {
            "k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16),
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self) -> dict:
        return {
            "k": Axes("layers", "cache_batch", "kv_seq", "act_kv", None),
            "v": Axes("layers", "cache_batch", "kv_seq", "act_kv", None),
            "length": Axes(),
        }

    def prefill(
        self, params, tokens, vision_embeds=None, *, q_chunk: int = 2048, pad_to: int | None = None
    ):
        """Run the full prompt, build the KV cache (padded to ``pad_to`` slots
        for subsequent decode steps), return last-token logits."""
        x, _aux, kvs = self.hidden_states(
            params, tokens, vision_embeds, collect_kv=True, q_chunk=q_chunk
        )
        k, v = kvs  # (L, B, T, K, hd)
        out_emb = params["embed"] if self.cfg.tie_embeddings else params["out_embed"]
        last = x[:, -1:, :]
        logits = logits_from_hidden(last, out_emb, self.cfg.vocab)[:, 0]
        T = tokens.shape[1]
        if pad_to is not None and pad_to > T:
            pad = [(0, 0), (0, 0), (0, pad_to - T), (0, 0), (0, 0)]
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
        cache = {
            "k": k.astype(jnp.bfloat16),
            "v": v.astype(jnp.bfloat16),
            "length": jnp.asarray(T, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache: dict, tokens: jax.Array):
        """tokens (B,1) — appends one position at cache['length']."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        B = tokens.shape[0]
        pos = cache["length"]
        x = embed_tokens(params["embed"], tokens, dtype)
        if cfg.pos_emb == "learned":
            x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1, 0).astype(dtype)
        sin, cos = rope_tables(pos[None], cfg.resolved_head_dim, cfg.rope_theta)

        stacked_axes = self._stacked_axes()

        from repro.dist.perf import perf

        use_sharded = perf().sharded_decode_attn

        def scan_fn(x, inputs):
            lp, kc, vc = inputs
            lp = constrain_tree(lp, stacked_axes, drop_leading=1)
            h = norm(x, lp["ln1"], cfg.rms_eps, cfg.norm_type)
            q, k, v = qkv(lp["attn"], h, cfg, sin, cos)
            if use_sharded:
                ao, kc, vc = attn_lib.sharded_decode_update_attend(q, kc, vc, k, v, pos)
            else:
                kc = attn_lib.update_cache(kc, k, pos)
                vc = attn_lib.update_cache(vc, v, pos)
                ao = attn_lib.decode_attention(q, kc, vc, pos + 1)
            ao = jnp.einsum(
                "bth,hd->btd", ao.reshape(B, 1, -1), lp["attn"]["wo"].astype(x.dtype)
            )
            if cfg.parallel_block:
                mo = apply_mlp(lp["mlp"], h, cfg)
                x = x + ao + mo
            else:
                x = x + ao
                h2 = norm(x, lp["ln2"], cfg.rms_eps, cfg.norm_type)
                if cfg.family == "moe":
                    mo, _ = moe_ffn(lp["moe"], h2, cfg)
                else:
                    mo = apply_mlp(lp["mlp"], h2, cfg)
                x = x + mo
            return constrain(x, ("batch", "seq", "embed")), (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            scan_fn, x, (self._stacked(params), cache["k"], cache["v"])
        )
        x = norm(x, params["ln_f"], cfg.rms_eps, cfg.norm_type)
        out_emb = params["embed"] if cfg.tie_embeddings else params["out_embed"]
        logits = logits_from_hidden(x, out_emb, cfg.vocab)[:, 0]
        new_cache = {"k": k_new, "v": v_new, "length": pos + 1}
        return logits, new_cache
