"""Shared model components: norms, RoPE, activations, initializers, losses.

Pure-functional style: every module is an ``init(key, ...) -> params`` plus
an ``apply(params, x, ...)``; params are nested dicts of arrays, with a
parallel pytree of :class:`repro.dist.Axes` logical-axis annotations used by
the sharding rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import Axes, constrain


def truncated_normal(key, shape, std: float, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm(x: jax.Array, scale: jax.Array, eps: float, kind: str) -> jax.Array:
    return rmsnorm(x, scale, eps) if kind == "rmsnorm" else layernorm(x, scale, eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int — returns (sin, cos) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., T, H, D); sin/cos: (..., T, D//2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    s = sin[..., None, :]
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def glu_activation(gate: jax.Array, up: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# embeddings + logits + loss
# ---------------------------------------------------------------------------

def init_embedding(key, cfg):
    V, d = cfg.padded_vocab, cfg.d_model
    emb = truncated_normal(key, (V, d), std=d**-0.5)
    # zero the padding rows so tied-logit rows stay inert
    emb = emb.at[cfg.vocab :].set(0.0)
    return emb


def embed_axes() -> Axes:
    return Axes("vocab", "param_embed")


def embed_tokens(emb: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    x = jnp.take(emb, tokens, axis=0).astype(dtype)
    return constrain(x, ("batch", "seq", "embed"))


def logits_from_hidden(x: jax.Array, out_emb: jax.Array, vocab: int) -> jax.Array:
    """x: (B, T, d), out_emb: (V, d) → fp32 logits with padded vocab masked."""
    logits = jnp.einsum("btd,vd->btv", x, out_emb.astype(x.dtype)).astype(jnp.float32)
    logits = constrain(logits, ("batch", "seq", "act_vocab"))
    V = out_emb.shape[0]
    if V != vocab:
        mask = jnp.arange(V) < vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """logits: (B, T, V) fp32; labels: (B, T) int. Returns (loss, metrics).

    Sharding-friendly: the label logit is extracted with a masked reduction
    (``take_along_axis`` over a vocab-sharded dim would force GSPMD to
    all-gather the full fp32 logits — tens of GiB per device at 128k vocab).
    """
    V = logits.shape[-1]
    mx = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=-1)) + mx[..., 0]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
    label_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - label_logit
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits, axis=-1) == labels) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
