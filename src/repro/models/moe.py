"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Tokens are routed top-k, sorted by expert id, gathered into an
``(E, C, d)`` capacity-bounded buffer, processed with batched per-expert
GLU matmuls (FLOPs ∝ active params — no dense all-expert compute), and
scatter-combined with the routing weights. Overflowing tokens are dropped
(standard capacity-factor semantics); the auxiliary load-balancing loss
keeps the router near-uniform.

Supports qwen2-moe-style shared experts: a dense GLU of width
``n_shared·d_ff`` gated by a per-token sigmoid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import Axes, constrain
from .common import glu_activation, truncated_normal


def init_moe(key, cfg):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = cfg.n_layers
    ks = jax.random.split(key, 8)
    p = {
        "router": truncated_normal(ks[0], (L, d, E), std=d**-0.5),
        "we_gate": truncated_normal(ks[1], (L, E, d, ff), std=d**-0.5),
        "we_up": truncated_normal(ks[2], (L, E, d, ff), std=d**-0.5),
        "we_down": truncated_normal(ks[3], (L, E, ff, d), std=ff**-0.5),
    }
    if cfg.n_shared_experts:
        ffs = cfg.n_shared_experts * ff
        p["ws_gate"] = truncated_normal(ks[4], (L, d, ffs), std=d**-0.5)
        p["ws_up"] = truncated_normal(ks[5], (L, d, ffs), std=d**-0.5)
        p["ws_down"] = truncated_normal(ks[6], (L, ffs, d), std=ffs**-0.5)
        p["ws_gate_scalar"] = truncated_normal(ks[7], (L, d), std=d**-0.5)
    return p


def moe_axes(cfg) -> dict:
    p = {
        "router": Axes("layers", "param_embed", None),
        "we_gate": Axes("layers", "experts", "param_embed", "mlp"),
        "we_up": Axes("layers", "experts", "param_embed", "mlp"),
        "we_down": Axes("layers", "experts", "mlp", "param_embed"),
    }
    if cfg.n_shared_experts:
        p["ws_gate"] = Axes("layers", "param_embed", "mlp")
        p["ws_up"] = Axes("layers", "param_embed", "mlp")
        p["ws_down"] = Axes("layers", "mlp", "param_embed")
        p["ws_gate_scalar"] = Axes("layers", "param_embed")
    return p


def moe_ffn(lp: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """lp: this layer's slice of the MoE params. x: (B, T, d).
    Returns (y, aux_loss). Dispatch is global under GSPMD by default;
    the §Perf V2 variant routes per data shard inside shard_map (auto over
    `model`), eliminating the global-scatter all-reduces."""
    from repro.dist.perf import perf

    if perf().moe_local_dispatch:
        y, aux = _moe_ffn_local(lp, x, cfg)
        if y is not None:
            return y, aux
    return _moe_tokens(lp, x, cfg)


def _moe_ffn_local(lp: dict, x: jax.Array, cfg):
    from jax.sharding import PartitionSpec as P

    from repro.dist import active_mesh, logical_to_spec, shard_map

    mesh = active_mesh()
    if mesh is None:
        return None, None
    x_spec = logical_to_spec(("batch", "seq", "embed"), x.shape, mesh)
    bspec = x_spec[0]
    if bspec is None:  # batch unsharded — local == global
        return None, None
    manual = set(bspec if isinstance(bspec, tuple) else (bspec,))

    def f(lp, x):
        y, aux = _moe_tokens(lp, x, cfg)
        axes = tuple(manual)
        for a in axes:
            aux = jax.lax.pmean(aux, a)
        return y, aux

    w_specs = jax.tree.map(lambda _: P(), lp)  # replicated over the manual axes
    y, aux = shard_map(
        f,
        mesh=mesh,
        in_specs=(w_specs, P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
        axis_names=frozenset(manual),
        check_vma=False,
    )(lp, x)
    return y, aux


def _moe_tokens(lp: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * T, d)
    N = B * T

    # --- routing (fp32) ---
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # (N, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # aux load-balance loss (Switch-style): E * sum_e f_e * P_e
    P_e = probs.mean(axis=0)
    ohot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (N,k,E)
    f_e = ohot.sum(axis=(0, 1)) / (N * k)
    aux = E * jnp.sum(f_e * P_e)

    # --- sort-based capacity dispatch ---
    C = int((N * k / E) * cfg.capacity_factor) + 1
    C = min(max(64, -(-C // 64) * 64), N)  # pad to 64 for MXU tiles, cap at N
    flat_e = top_i.reshape(-1)  # (N*k,)
    sort_idx = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[sort_idx]
    grp_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_grp = jnp.arange(N * k) - grp_start[sorted_e]
    keep = pos_in_grp < C
    token_idx = sort_idx // k  # source token of each routed slot
    gate_sorted = top_p.reshape(-1)[sort_idx]

    # (E, C) token table; N = out-of-band → gathers the zero pad row
    table = jnp.full((E, C), N, dtype=jnp.int32)
    table = table.at[sorted_e, jnp.where(keep, pos_in_grp, 0)].set(
        jnp.where(keep, token_idx, N), mode="drop"
    )
    gates = jnp.zeros((E, C), dtype=jnp.float32)
    gates = gates.at[sorted_e, jnp.where(keep, pos_in_grp, 0)].set(
        jnp.where(keep, gate_sorted, 0.0), mode="drop"
    )

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), dtype=xt.dtype)], axis=0)
    xe = xpad[table]  # (E, C, d)
    xe = constrain(xe, ("act_experts", None, "embed"))

    h = glu_activation(
        jnp.einsum("ecd,edf->ecf", xe, lp["we_gate"].astype(xe.dtype)),
        jnp.einsum("ecd,edf->ecf", xe, lp["we_up"].astype(xe.dtype)),
        cfg.activation,
    )
    h = constrain(h, ("act_experts", None, "act_mlp"))
    ye = jnp.einsum("ecf,efd->ecd", h, lp["we_down"].astype(h.dtype))
    ye = ye * gates[..., None].astype(ye.dtype)

    # scatter-combine back to tokens
    y = jnp.zeros((N + 1, d), dtype=ye.dtype)
    y = y.at[table.reshape(-1)].add(ye.reshape(-1, d), mode="drop")
    y = y[:N]

    # --- shared experts (dense path) ---
    if cfg.n_shared_experts:
        hs = glu_activation(
            jnp.einsum("nd,df->nf", xt, lp["ws_gate"].astype(xt.dtype)),
            jnp.einsum("nd,df->nf", xt, lp["ws_up"].astype(xt.dtype)),
            cfg.activation,
        )
        ys = jnp.einsum("nf,fd->nd", hs, lp["ws_down"].astype(hs.dtype))
        g = jax.nn.sigmoid(
            jnp.einsum("nd,d->n", xt.astype(jnp.float32), lp["ws_gate_scalar"].astype(jnp.float32))
        )
        y = y + ys * g[:, None].astype(ys.dtype)

    return y.reshape(B, T, d), aux.astype(jnp.float32)
