"""Mamba-2 (SSD — state-space duality), attention-free LM.

Chunked SSD per the paper's Listing 1 (arXiv:2405.21060): within a chunk the
recurrence is computed as an attention-like quadratic block (MXU-friendly);
across chunks a small (H, P, N) state is carried by a scan. Decode is an
O(1) recurrent state update — seq_len-independent, which is why this arch
runs the ``long_500k`` cell (see DESIGN.md §Arch-applicability).

Layout: x (B, T, H, P) heads; B/C (B, T, G, N) groups (G=1 for mamba2-1.3b);
state (B, H, P, N).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.dist import Axes, constrain, constrain_tree
from .common import (
    embed_axes,
    embed_tokens,
    init_embedding,
    logits_from_hidden,
    rmsnorm,
    softmax_cross_entropy,
    truncated_normal,
)

NEG_INF = -1e30


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_in, nh, conv_dim


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dA, B, C, chunk: int, h0=None):
    """x (b,t,h,p); dA (b,t,h) log-decay (≤0); B,C (b,t,g,n).
    Returns (y (b,t,h,p), final_state (b,h,p,n))."""
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    t_orig = t
    if t % chunk:
        # pad with identity steps: dA=0 (decay 1), B·x=0 — state unaffected
        pad = chunk - t % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = x.shape[1]
    nc = t // chunk

    xc = x.reshape(b, nc, chunk, g, hpg, p)
    Ac = dA.reshape(b, nc, chunk, g, hpg)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    cum = jnp.cumsum(Ac, axis=2)  # (b,nc,cs,g,hpg)

    # --- intra-chunk (diagonal blocks) ---
    seg = cum[:, :, :, None] - cum[:, :, None, :]  # (b,nc,i,j,g,hpg)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]
    L = jnp.exp(jnp.where(causal[None, None, :, :, None, None], seg, NEG_INF))
    CB = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc, preferred_element_type=jnp.float32)
    scores = CB[..., None] * L  # (b,nc,i,j,g,hpg)
    y_diag = jnp.einsum("bcijgh,bcjghp->bcighp", scores.astype(x.dtype), xc)

    # --- chunk states ---
    decay_states = jnp.exp(cum[:, :, -1:] - cum)  # (b,nc,cs,g,hpg)
    S = jnp.einsum("bcjgn,bcjgh,bcjghp->bcghpn", Bc, decay_states.astype(x.dtype), xc)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cum[:, :, -1])  # (b,nc,g,hpg)
    if h0 is None:
        h0 = jnp.zeros((b, g, hpg, p, n), x.dtype)

    def step(Hprev, inp):
        S_c, dec_c = inp  # (b,g,hpg,p,n), (b,g,hpg)
        H_new = dec_c[..., None, None].astype(x.dtype) * Hprev + S_c
        return H_new, Hprev  # emit state ENTERING this chunk

    S_sw = jnp.moveaxis(S, 1, 0)  # (nc,b,g,hpg,p,n)
    dec_sw = jnp.moveaxis(chunk_decay, 1, 0)
    H_last, H_in = jax.lax.scan(step, h0, (S_sw, dec_sw))
    H_in = jnp.moveaxis(H_in, 0, 1)  # (b,nc,g,hpg,p,n)

    # --- off-diagonal contribution from carried state ---
    state_decay = jnp.exp(cum)  # (b,nc,cs,g,hpg)
    y_off = jnp.einsum(
        "bcign,bcghpn,bcigh->bcighp", Cc, H_in, state_decay.astype(x.dtype)
    )

    y = (y_diag + y_off).reshape(b, t, h, p)[:, :t_orig]
    return y, H_last.reshape(b, h, p, n)


def ssd_decode_step(state, x, dA, B, C):
    """One-token update (fp32 state). state (b,h,p,n); x (b,h,p); dA (b,h);
    B,C (b,g,n)."""
    b, h, p, n = state.shape
    g = B.shape[1]
    hpg = h // g
    st = state.reshape(b, g, hpg, p, n).astype(jnp.float32)
    xg = x.reshape(b, g, hpg, p).astype(jnp.float32)
    dAg = jnp.exp(dA).reshape(b, g, hpg)
    st = st * dAg[..., None, None] + jnp.einsum(
        "bgn,bghp->bghpn", B.astype(jnp.float32), xg
    )
    y = jnp.einsum("bgn,bghpn->bghp", C.astype(jnp.float32), st)
    return y.reshape(b, h, p).astype(x.dtype), st.reshape(b, h, p, n)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


class Mamba2LM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        d = cfg.d_model
        L = cfg.n_layers
        d_in, nh, conv_dim = _dims(cfg)
        proj_out = 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + nh
        ks = jax.random.split(key, 5)
        p = {
            "embed": init_embedding(ks[0], cfg),
            "ln": jnp.zeros((L, d)),
            "ln_f": jnp.zeros((d,)),
            "in_proj": truncated_normal(ks[1], (L, d, proj_out), std=d**-0.5),
            "conv_w": truncated_normal(ks[2], (L, conv_dim, cfg.conv_kernel), std=0.2),
            "conv_b": jnp.zeros((L, conv_dim)),
            "A_log": jnp.log(
                jnp.tile(jnp.linspace(1.0, 16.0, nh)[None, :], (L, 1))
            ),
            "dt_bias": jnp.full((L, nh), -2.0),
            "D": jnp.ones((L, nh)),
            "norm": jnp.zeros((L, d_in)),
            "out_proj": truncated_normal(ks[3], (L, d_in, d), std=d_in**-0.5),
        }
        if not cfg.tie_embeddings:
            p["out_embed"] = init_embedding(ks[4], cfg)
        return p

    def param_axes(self):
        cfg = self.cfg
        p = {
            "embed": embed_axes(),
            "ln": Axes("layers", "param_embed"),
            "ln_f": Axes("param_embed"),
            "in_proj": Axes("layers", "param_embed", "rnn_width"),
            "conv_w": Axes("layers", "conv_dim", None),
            "conv_b": Axes("layers", "conv_dim"),
            "A_log": Axes("layers", "ssm_heads"),
            "dt_bias": Axes("layers", "ssm_heads"),
            "D": Axes("layers", "ssm_heads"),
            "norm": Axes("layers", "rnn_width"),
            "out_proj": Axes("layers", "rnn_width", "param_embed"),
        }
        if not cfg.tie_embeddings:
            p["out_embed"] = embed_axes()
        return p

    # -- per-layer pieces --------------------------------------------------
    def _split_proj(self, zxbcdt):
        cfg = self.cfg
        d_in, nh, _ = _dims(cfg)
        gn = cfg.ssm_groups * cfg.ssm_state
        z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + d_in + 2 * gn], axis=-1)
        return z, xBC, dt

    def _conv(self, lp, xBC, conv_state=None):
        """Causal depthwise conv along T. xBC (B,T,conv_dim)."""
        k = self.cfg.conv_kernel
        w = lp["conv_w"].astype(xBC.dtype)  # (conv_dim, k)
        if conv_state is None:
            pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
        else:
            pad = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
        out = sum(
            pad[:, i : i + xBC.shape[1], :] * w[:, i][None, None, :] for i in range(k)
        )
        return jax.nn.silu(out + lp["conv_b"].astype(xBC.dtype))

    def _layer(self, x, lp, *, decode_state=None):
        cfg = self.cfg
        d_in, nh, conv_dim = _dims(cfg)
        hd = cfg.ssm_head_dim
        g, n = cfg.ssm_groups, cfg.ssm_state
        B_, T, _ = x.shape
        h = rmsnorm(x, lp["ln"], cfg.rms_eps)
        zxbcdt = jnp.einsum("btd,dk->btk", h, lp["in_proj"].astype(h.dtype))
        z, xBC, dt = self._split_proj(zxbcdt)

        new_conv_state = None
        if decode_state is not None:
            conv_state, ssm_state = decode_state
            new_conv_state = jnp.concatenate([conv_state[:, 1:], xBC], axis=1)
            xBC = self._conv(lp, xBC, conv_state)
        else:
            xBC = self._conv(lp, xBC)
        xBC = constrain(xBC, ("batch", "seq", "conv_dim"))

        xs, Bc, Cc = jnp.split(xBC, [d_in, d_in + g * n], axis=-1)
        xs = xs.reshape(B_, T, nh, hd)
        Bc = Bc.reshape(B_, T, g, n)
        Cc = Cc.reshape(B_, T, g, n)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))  # (nh,)
        dA = dt * A  # (B,T,nh) log-decay
        x_in = xs * dt.astype(xs.dtype)[..., None]

        if decode_state is not None:
            y, new_ssm = ssd_decode_step(
                ssm_state, x_in[:, 0], dA[:, 0], Bc[:, 0], Cc[:, 0]
            )
            y = y[:, None]
            new_state = (new_conv_state, new_ssm)
        else:
            y, _ = ssd_chunked(x_in, dA, Bc, Cc, min(cfg.ssm_chunk, T))
            new_state = None
        y = y + lp["D"].astype(y.dtype)[None, None, :, None] * xs
        y = y.reshape(B_, T, d_in)
        y = rmsnorm(y * jax.nn.silu(z), lp["norm"], cfg.rms_eps)
        y = constrain(y, ("batch", "seq", "rnn_width"))
        out = jnp.einsum("btk,kd->btd", y, lp["out_proj"].astype(y.dtype))
        return constrain(x + out, ("batch", "seq", "embed")), new_state

    def _stacked_axes(self):
        ax = self.param_axes()
        return {k: ax[k] for k in (
            "ln", "in_proj", "conv_w", "conv_b", "A_log", "dt_bias", "D", "norm", "out_proj")}

    def _stacked(self, params):
        return {
            k: params[k]
            for k in (
                "ln",
                "in_proj",
                "conv_w",
                "conv_b",
                "A_log",
                "dt_bias",
                "D",
                "norm",
                "out_proj",
            )
        }

    # -- public api ---------------------------------------------------------
    def forward(self, params, tokens, vision_embeds=None, *, remat=False, q_chunk=0):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
        body = self._layer
        if remat:
            body = jax.checkpoint(
                lambda x, lp: self._layer(x, lp),
                policy=jax.checkpoint_policies.nothing_saveable,
            )

        stacked_axes = self._stacked_axes()

        def scan_fn(x, lp):
            lp = constrain_tree(lp, stacked_axes, drop_leading=1)
            x, _ = body(x, lp)
            return x, None

        x, _ = jax.lax.scan(scan_fn, x, self._stacked(params))
        x = rmsnorm(x, params["ln_f"], cfg.rms_eps)
        out_emb = params["embed"] if cfg.tie_embeddings else params["out_embed"]
        return logits_from_hidden(x, out_emb, cfg.vocab), jnp.zeros((), jnp.float32)

    def loss(self, params, batch, *, remat=True, q_chunk=0):
        logits, _ = self.forward(params, batch["tokens"], remat=remat)
        loss, metrics = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
        return loss, metrics

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        d_in, nh, conv_dim = _dims(cfg)
        L = cfg.n_layers
        return {
            "conv": jnp.zeros((L, batch, cfg.conv_kernel - 1, conv_dim), jnp.bfloat16),
            "ssm": jnp.zeros((L, batch, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        return {
            "conv": Axes("layers", "cache_batch", None, "conv_dim"),
            "ssm": Axes("layers", "cache_batch", "ssm_heads", None, "ssm_state"),
            "length": Axes(),
        }

    def prefill(self, params, tokens, *, pad_to=None, q_chunk=0):
        """Sequential state build via per-layer full scan, emitting final states."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
        d_in, nh, conv_dim = _dims(cfg)
        k = cfg.conv_kernel

        stacked_axes = self._stacked_axes()

        def scan_fn(x, lp):
            # replicate _layer but emit (conv_state, ssm_state)
            lp = constrain_tree(lp, stacked_axes, drop_leading=1)
            B_, T, _ = x.shape
            h = rmsnorm(x, lp["ln"], cfg.rms_eps)
            zxbcdt = jnp.einsum("btd,dk->btk", h, lp["in_proj"].astype(h.dtype))
            z, xBC, dt = self._split_proj(zxbcdt)
            conv_tail = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1) :, :]
            xBC = self._conv(lp, xBC)
            xs, Bc, Cc = jnp.split(
                xBC, [d_in, d_in + cfg.ssm_groups * cfg.ssm_state], axis=-1
            )
            xs = xs.reshape(B_, T, nh, cfg.ssm_head_dim)
            Bc = Bc.reshape(B_, T, cfg.ssm_groups, cfg.ssm_state)
            Cc = Cc.reshape(B_, T, cfg.ssm_groups, cfg.ssm_state)
            dtf = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
            A = -jnp.exp(lp["A_log"].astype(jnp.float32))
            x_in = xs * dtf.astype(xs.dtype)[..., None]
            y, ssm_state = ssd_chunked(x_in, dtf * A, Bc, Cc, min(cfg.ssm_chunk, T))
            y = y + lp["D"].astype(y.dtype)[None, None, :, None] * xs
            y = y.reshape(B_, T, d_in)
            y = rmsnorm(y * jax.nn.silu(z), lp["norm"], cfg.rms_eps)
            out = jnp.einsum("btk,kd->btd", y, lp["out_proj"].astype(y.dtype))
            return x + out, (conv_tail.astype(jnp.bfloat16), ssm_state.astype(jnp.float32))

        x, (conv_states, ssm_states) = jax.lax.scan(scan_fn, x, self._stacked(params))
        x = rmsnorm(x, params["ln_f"], cfg.rms_eps)
        out_emb = params["embed"] if cfg.tie_embeddings else params["out_embed"]
        logits = logits_from_hidden(x[:, -1:], out_emb, cfg.vocab)[:, 0]
        cache = {
            "conv": conv_states,
            "ssm": ssm_states,
            "length": jnp.asarray(tokens.shape[1], jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))

        stacked_axes = self._stacked_axes()

        def scan_fn(x, inputs):
            lp, conv_s, ssm_s = inputs
            lp = constrain_tree(lp, stacked_axes, drop_leading=1)
            x, (conv_s, ssm_s) = self._layer(x, lp, decode_state=(conv_s, ssm_s))
            return x, (conv_s.astype(jnp.bfloat16), ssm_s.astype(jnp.float32))

        x, (conv_new, ssm_new) = jax.lax.scan(
            scan_fn, x, (self._stacked(params), cache["conv"], cache["ssm"])
        )
        x = rmsnorm(x, params["ln_f"], cfg.rms_eps)
        out_emb = params["embed"] if cfg.tie_embeddings else params["out_embed"]
        logits = logits_from_hidden(x, out_emb, cfg.vocab)[:, 0]
        return logits, {"conv": conv_new, "ssm": ssm_new, "length": cache["length"] + 1}
