"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local sliding
window attention, cyclic layer pattern (default R,R,A).

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t)            (recurrence gate)
    i_t = sigmoid(W_x x_t)            (input gate)
    a_t = exp(-c · softplus(Λ) · r_t) (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Train uses an associative scan over T (linear-diagonal recurrence); decode
is O(1). Local attention uses a ring-buffer KV cache of ``window`` slots —
O(window) decode memory, which is why this arch runs ``long_500k``.

Because the two layer kinds have different param trees, depth is organized
as ``n_groups`` repetitions of the pattern, scanned with ``lax.scan`` (one
stacked param set per *slot* of the pattern), plus an unrolled remainder
(38 = 12×(R,R,A) + R,R for the 9b config).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import Axes, constrain, constrain_tree
from . import attention as attn_lib
from .common import (
    embed_axes,
    embed_tokens,
    init_embedding,
    logits_from_hidden,
    rmsnorm,
    rope_tables,
    softmax_cross_entropy,
    truncated_normal,
)
from .transformer import apply_mlp, attn_axes, init_attn, init_mlp, mlp_axes, qkv

_C = 8.0  # RG-LRU temperature


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rglru_scan(x, r, i, lam, h0=None):
    """x, r, i: (B, T, W); lam: (W,). Returns (y (B,T,W), h_last (B,W) fp32)."""
    log_a_base = -_C * jax.nn.softplus(lam.astype(jnp.float32))  # (W,) ≤ 0
    log_a = r.astype(jnp.float32) * log_a_base
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    u = beta * (i.astype(jnp.float32) * x.astype(jnp.float32))

    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, u1 * a2 + u2

    aT = jnp.moveaxis(a, 1, 0)
    uT = jnp.moveaxis(u, 1, 0)
    a_acc, u_acc = jax.lax.associative_scan(combine, (aT, uT), axis=0)
    h = a_acc * h0[None] + u_acc  # (T,B,W)
    y = jnp.moveaxis(h, 0, 1)
    return y.astype(x.dtype), y[:, -1].astype(jnp.float32)


def rglru_step(h, x, r, i, lam):
    """One decode step. h (B,W) fp32; x,r,i (B,W)."""
    log_a = r.astype(jnp.float32) * (-_C * jax.nn.softplus(lam.astype(jnp.float32)))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a * h + beta * (i.astype(jnp.float32) * x.astype(jnp.float32))
    return h.astype(x.dtype), h


def _causal_conv(xw, conv_w, conv_b, state=None):
    """Depthwise causal conv along T. xw (B,T,W); conv_w (W,k);
    state (B,k-1,W) holds the previous inputs for decode."""
    k = conv_w.shape[-1]
    w = conv_w.astype(xw.dtype)
    if state is None:
        pad = jnp.pad(xw, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(xw.dtype), xw], axis=1)
    out = sum(pad[:, i : i + xw.shape[1], :] * w[:, i][None, None, :] for i in range(k))
    return out + conv_b.astype(xw.dtype)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_rec_block(key, cfg, L: int):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_in": truncated_normal(ks[0], (L, d, w), std=d**-0.5),
        "w_gate_branch": truncated_normal(ks[1], (L, d, w), std=d**-0.5),
        "conv_w": truncated_normal(ks[2], (L, w, cfg.conv_kernel), std=0.2),
        "conv_b": jnp.zeros((L, w)),
        "w_a": truncated_normal(ks[3], (L, w, w), std=w**-0.5),
        "w_x": truncated_normal(ks[4], (L, w, w), std=w**-0.5),
        "lam": jnp.tile(jnp.linspace(0.5, 4.0, w)[None], (L, 1)),
        "w_out": truncated_normal(ks[5], (L, w, d), std=w**-0.5),
    }


def rec_block_axes() -> dict:
    return {
        "w_in": Axes("layers", "param_embed", "rnn_width"),
        "w_gate_branch": Axes("layers", "param_embed", "rnn_width"),
        "conv_w": Axes("layers", "rnn_width", None),
        "conv_b": Axes("layers", "rnn_width"),
        "w_a": Axes("layers", "param_embed", "rnn_width"),
        "w_x": Axes("layers", "param_embed", "rnn_width"),
        "lam": Axes("layers", "rnn_width"),
        "w_out": Axes("layers", "rnn_width", "param_embed"),
    }


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class GriffinLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.pattern = cfg.layer_pattern or "A"
        self.plen = len(self.pattern)
        self.n_groups = cfg.n_layers // self.plen
        self.rem = self.pattern[: cfg.n_layers - self.n_groups * self.plen]

    # -- slots -----------------------------------------------------------------
    def _init_slot(self, key, kind: str, L: int) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        slot = {
            "ln1": jnp.zeros((L, cfg.d_model)),
            "ln2": jnp.zeros((L, cfg.d_model)),
            "mlp": init_mlp(k2, cfg, L),
        }
        slot["mix"] = init_rec_block(k1, cfg, L) if kind == "R" else init_attn(k1, cfg, L)
        return slot

    def _slot_axes(self, kind: str) -> dict:
        cfg = self.cfg
        return {
            "ln1": Axes("layers", "param_embed"),
            "ln2": Axes("layers", "param_embed"),
            "mlp": mlp_axes(cfg),
            "mix": rec_block_axes() if kind == "R" else attn_axes(cfg),
        }

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3 + self.plen + len(self.rem))
        p = {
            "embed": init_embedding(ks[0], cfg),
            "ln_f": jnp.zeros((cfg.d_model,)),
            "slots": [
                self._init_slot(ks[2 + s], kind, self.n_groups)
                for s, kind in enumerate(self.pattern)
            ],
            "rem": [
                self._init_slot(ks[2 + self.plen + s], kind, 1)
                for s, kind in enumerate(self.rem)
            ],
        }
        if not cfg.tie_embeddings:
            p["out_embed"] = init_embedding(ks[1], cfg)
        return p

    def param_axes(self):
        p = {
            "embed": embed_axes(),
            "ln_f": Axes("param_embed"),
            "slots": [self._slot_axes(k) for k in self.pattern],
            "rem": [self._slot_axes(k) for k in self.rem],
        }
        if not self.cfg.tie_embeddings:
            p["out_embed"] = embed_axes()
        return p

    # -- one layer ------------------------------------------------------------
    def _apply_layer(self, x, lp, kind, sin, cos, cache=None, pos=None):
        """cache: None (train) or dict for this layer. Returns (x, new_cache)."""
        cfg = self.cfg
        h = rmsnorm(x, lp["ln1"], cfg.rms_eps)
        new_cache = {}
        if kind == "R":
            xw = jnp.einsum("btd,dw->btw", h, lp["mix"]["w_in"].astype(h.dtype))
            gate = jax.nn.gelu(
                jnp.einsum("btd,dw->btw", h, lp["mix"]["w_gate_branch"].astype(h.dtype)),
                approximate=True,
            )
            k = cfg.conv_kernel
            if cache is None:
                conv_in, h0 = None, None
            else:
                conv_in, h0 = cache["conv"], cache["h"]
            tail_src = (
                xw if cache is None else jnp.concatenate([conv_in.astype(xw.dtype), xw], 1)
            )
            conv_tail = jnp.pad(tail_src, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1) :]
            xc = _causal_conv(xw, lp["mix"]["conv_w"], lp["mix"]["conv_b"], conv_in)
            xc = constrain(xc, ("batch", "seq", "rnn_width"))
            r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xc, lp["mix"]["w_a"].astype(xc.dtype)))
            i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xc, lp["mix"]["w_x"].astype(xc.dtype)))
            if cache is None:
                y, h_last = rglru_scan(xc, r, i, lp["mix"]["lam"])
            else:
                y1, h_last = rglru_step(h0, xc[:, 0], r[:, 0], i[:, 0], lp["mix"]["lam"])
                y = y1[:, None]
            y = y * gate
            mix_out = jnp.einsum("btw,wd->btd", y, lp["mix"]["w_out"].astype(y.dtype))
            new_cache = {"conv": conv_tail.astype(jnp.bfloat16), "h": h_last}
        else:  # local attention
            q, kk, vv = qkv(lp["mix"], h, cfg, sin, cos)
            if cache is None:
                ao = attn_lib.full_attention(
                    q, kk, vv, causal=True, window=cfg.window, q_chunk=2048
                )
            else:
                kc = attn_lib.update_cache(cache["k"], kk, pos, ring=True)
                vc = attn_lib.update_cache(cache["v"], vv, pos, ring=True)
                valid = jnp.minimum(pos + 1, cfg.window)
                ao = attn_lib.decode_attention(q, kc, vc, valid)
                new_cache = {"k": kc, "v": vc}
            mix_out = jnp.einsum(
                "bth,hd->btd",
                ao.reshape(ao.shape[0], ao.shape[1], -1),
                lp["mix"]["wo"].astype(x.dtype),
            )
        x = x + mix_out
        h2 = rmsnorm(x, lp["ln2"], cfg.rms_eps)
        x = x + apply_mlp(lp["mlp"], h2, cfg)
        return constrain(x, ("batch", "seq", "embed")), new_cache

    # -- forward ----------------------------------------------------------------
    def forward(self, params, tokens, vision_embeds=None, *, remat=False, q_chunk=0):
        cfg = self.cfg
        B, T = tokens.shape
        x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
        sin, cos = rope_tables(jnp.arange(T), cfg.resolved_head_dim, cfg.rope_theta)

        slot_axes = [self._slot_axes(k) for k in self.pattern]

        def group_body(x, slot_params):
            for s, kind in enumerate(self.pattern):
                lp = constrain_tree(slot_params[s], slot_axes[s], drop_leading=1)
                x, _ = self._apply_layer(x, lp, kind, sin, cos)
            return x

        body = group_body
        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

        if self.n_groups:
            x, _ = jax.lax.scan(lambda c, xs: (body(c, xs), None), x, params["slots"])
        for s, kind in enumerate(self.rem):
            lp = jax.tree.map(lambda a: a[0], params["rem"][s])
            x, _ = self._apply_layer(x, lp, kind, sin, cos)
        x = rmsnorm(x, params["ln_f"], cfg.rms_eps)
        out_emb = params["embed"] if cfg.tie_embeddings else params["out_embed"]
        return logits_from_hidden(x, out_emb, cfg.vocab), jnp.zeros((), jnp.float32)

    def loss(self, params, batch, *, remat=True, q_chunk=0):
        logits, _ = self.forward(params, batch["tokens"], remat=remat)
        loss, metrics = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
        return loss, metrics

    # -- serving -------------------------------------------------------------
    def _empty_caches(self, batch: int, G: int) -> list:
        """Per-slot stacked caches with leading group dim G."""
        cfg = self.cfg
        w = cfg.rnn_width or cfg.d_model
        K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        caches = []
        for kind in self.pattern:
            if kind == "R":
                caches.append(
                    {
                        "conv": jnp.zeros((G, batch, cfg.conv_kernel - 1, w), jnp.bfloat16),
                        "h": jnp.zeros((G, batch, w), jnp.float32),
                    }
                )
            else:
                caches.append(
                    {
                        "k": jnp.zeros((G, batch, cfg.window, K, hd), jnp.bfloat16),
                        "v": jnp.zeros((G, batch, cfg.window, K, hd), jnp.bfloat16),
                    }
                )
        return caches

    def init_cache(self, batch: int, max_len: int):
        rem_caches = [
            jax.tree.map(lambda a: a[0], self._empty_caches(batch, 1)[s])
            for s, kind in enumerate(self.rem)
        ]
        return {
            "slots": self._empty_caches(batch, self.n_groups),
            "rem": rem_caches,
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        def slot_ax(kind):
            if kind == "R":
                return {
                    "conv": Axes("layers", "batch", None, "rnn_width"),
                    "h": Axes("layers", "cache_batch", "rnn_width"),
                }
            return {
                "k": Axes("layers", "cache_batch", "kv_seq", "act_kv", None),
                "v": Axes("layers", "cache_batch", "kv_seq", "act_kv", None),
            }

        def rem_ax(kind):
            return jax.tree.map(lambda ax: Axes(*ax.t[1:]), slot_ax(kind))

        return {
            "slots": [slot_ax(k) for k in self.pattern],
            "rem": [rem_ax(k) for k in self.rem],
            "length": Axes(),
        }

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        pos = cache["length"]
        x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
        sin, cos = rope_tables(pos[None], cfg.resolved_head_dim, cfg.rope_theta)

        slot_axes = [self._slot_axes(k) for k in self.pattern]

        def group_body(x, xs):
            slot_params, slot_caches = xs
            new_caches = []
            for s, kind in enumerate(self.pattern):
                lp = constrain_tree(slot_params[s], slot_axes[s], drop_leading=1)
                x, nc = self._apply_layer(
                    x, lp, kind, sin, cos, cache=slot_caches[s], pos=pos
                )
                new_caches.append(nc)
            return x, new_caches

        if self.n_groups:
            x, new_slot_caches = jax.lax.scan(
                group_body, x, (params["slots"], cache["slots"])
            )
        else:
            new_slot_caches = cache["slots"]
        new_rem = []
        for s, kind in enumerate(self.rem):
            lp = jax.tree.map(lambda a: a[0], params["rem"][s])
            x, nc = self._apply_layer(x, lp, kind, sin, cos, cache=cache["rem"][s], pos=pos)
            new_rem.append(nc)
        x = rmsnorm(x, params["ln_f"], cfg.rms_eps)
        out_emb = params["embed"] if cfg.tie_embeddings else params["out_embed"]
        logits = logits_from_hidden(x, out_emb, cfg.vocab)[:, 0]
        return logits, {"slots": new_slot_caches, "rem": new_rem, "length": pos + 1}

    def prefill(self, params, tokens, *, pad_to=None, q_chunk=0):
        """Run the prompt and build decode caches (ring KV for A slots,
        conv tail + RG-LRU state for R slots)."""
        cfg = self.cfg
        B, T = tokens.shape
        x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
        sin, cos = rope_tables(jnp.arange(T), cfg.resolved_head_dim, cfg.rope_theta)
        W = cfg.window

        def ring_from_full(kv):  # (B,T,K,hd) → (B,W,K,hd) ring layout
            n = min(T, W)
            start = T - n
            idx = (start + jnp.arange(n)) % W
            ring = jnp.zeros((B, W) + kv.shape[2:], jnp.bfloat16)
            return ring.at[:, idx].set(kv[:, start:].astype(jnp.bfloat16))

        def layer_with_cache(x, lp, kind):
            cfg_ = self.cfg
            h = rmsnorm(x, lp["ln1"], cfg_.rms_eps)
            if kind == "R":
                xw = jnp.einsum("btd,dw->btw", h, lp["mix"]["w_in"].astype(h.dtype))
                gate = jax.nn.gelu(
                    jnp.einsum("btd,dw->btw", h, lp["mix"]["w_gate_branch"].astype(h.dtype)),
                    approximate=True,
                )
                k = cfg_.conv_kernel
                conv_tail = jnp.pad(xw, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1) :]
                xc = _causal_conv(xw, lp["mix"]["conv_w"], lp["mix"]["conv_b"])
                r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xc, lp["mix"]["w_a"].astype(xc.dtype)))
                i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xc, lp["mix"]["w_x"].astype(xc.dtype)))
                y, h_last = rglru_scan(xc, r, i, lp["mix"]["lam"])
                y = y * gate
                mix_out = jnp.einsum("btw,wd->btd", y, lp["mix"]["w_out"].astype(y.dtype))
                nc = {"conv": conv_tail.astype(jnp.bfloat16), "h": h_last}
            else:
                q, kk, vv = qkv(lp["mix"], h, cfg_, sin, cos)
                ao = attn_lib.full_attention(q, kk, vv, causal=True, window=W, q_chunk=2048)
                mix_out = jnp.einsum(
                    "bth,hd->btd",
                    ao.reshape(ao.shape[0], ao.shape[1], -1),
                    lp["mix"]["wo"].astype(x.dtype),
                )
                nc = {"k": ring_from_full(kk), "v": ring_from_full(vv)}
            x = x + mix_out
            h2 = rmsnorm(x, lp["ln2"], cfg_.rms_eps)
            x = x + apply_mlp(lp["mlp"], h2, cfg_)
            return constrain(x, ("batch", "seq", "embed")), nc

        slot_axes = [self._slot_axes(k) for k in self.pattern]

        def group_body(x, slot_params):
            ncs = []
            for s, kind in enumerate(self.pattern):
                lp = constrain_tree(slot_params[s], slot_axes[s], drop_leading=1)
                x, nc = layer_with_cache(x, lp, kind)
                ncs.append(nc)
            return x, ncs

        if self.n_groups:
            x, slot_caches = jax.lax.scan(group_body, x, params["slots"])
        else:
            slot_caches = self._empty_caches(B, 0)
        rem_caches = []
        for s, kind in enumerate(self.rem):
            lp = jax.tree.map(lambda a: a[0], params["rem"][s])
            x, nc = layer_with_cache(x, lp, kind)
            rem_caches.append(nc)
        x = rmsnorm(x, params["ln_f"], cfg.rms_eps)
        out_emb = params["embed"] if cfg.tie_embeddings else params["out_embed"]
        logits = logits_from_hidden(x[:, -1:], out_emb, cfg.vocab)[:, 0]
        cache = {
            "slots": slot_caches,
            "rem": rem_caches,
            "length": jnp.asarray(T, jnp.int32),
        }
        return logits, cache
