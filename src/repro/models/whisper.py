"""Whisper-style encoder-decoder (audio family).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, enc_len, d_model); the encoder is
bidirectional self-attention over those frames (sinusoidal positions), the
decoder is a causal LM with cross-attention (learned positions, tied
output embedding). LayerNorm + GELU MLP per the original architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import Axes, constrain, constrain_tree
from . import attention as attn_lib
from .common import (
    embed_axes,
    embed_tokens,
    init_embedding,
    layernorm,
    logits_from_hidden,
    softmax_cross_entropy,
    truncated_normal,
)
from .transformer import apply_mlp, attn_axes, init_attn, init_mlp, mlp_axes, qkv


def sinusoid_pos(T: int, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(T)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel:
    def __init__(self, cfg):
        self.cfg = cfg
        self.max_dec_pos = 40960  # covers the 32k prefill/decode cells

    # -- init ----------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        Le, Ld = cfg.enc_layers, cfg.n_layers
        ks = jax.random.split(key, 10)
        p = {
            "embed": init_embedding(ks[0], cfg),
            "dec_pos": truncated_normal(ks[1], (self.max_dec_pos, cfg.d_model), std=0.02),
            "enc": {
                "ln1": jnp.zeros((Le, cfg.d_model)),
                "ln2": jnp.zeros((Le, cfg.d_model)),
                "attn": init_attn(ks[2], cfg, Le),
                "mlp": init_mlp(ks[3], cfg, Le),
            },
            "enc_ln_f": jnp.zeros((cfg.d_model,)),
            "dec": {
                "ln1": jnp.zeros((Ld, cfg.d_model)),
                "ln2": jnp.zeros((Ld, cfg.d_model)),
                "ln3": jnp.zeros((Ld, cfg.d_model)),
                "attn": init_attn(ks[4], cfg, Ld),
                "cross": init_attn(ks[5], cfg, Ld),
                "mlp": init_mlp(ks[6], cfg, Ld),
            },
            "dec_ln_f": jnp.zeros((cfg.d_model,)),
        }
        return p

    def param_axes(self):
        cfg = self.cfg
        enc = {
            "ln1": Axes("layers", "param_embed"),
            "ln2": Axes("layers", "param_embed"),
            "attn": attn_axes(cfg),
            "mlp": mlp_axes(cfg),
        }
        dec = {
            "ln1": Axes("layers", "param_embed"),
            "ln2": Axes("layers", "param_embed"),
            "ln3": Axes("layers", "param_embed"),
            "attn": attn_axes(cfg),
            "cross": attn_axes(cfg),
            "mlp": mlp_axes(cfg),
        }
        return {
            "embed": embed_axes(),
            "dec_pos": Axes("param_seq", "param_embed"),
            "enc": enc,
            "enc_ln_f": Axes("param_embed"),
            "dec": dec,
            "dec_ln_f": Axes("param_embed"),
        }

    # -- encoder -----------------------------------------------------------
    def encode(self, params, enc_embeds):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = enc_embeds.astype(dtype)
        x = x + sinusoid_pos(x.shape[1], cfg.d_model).astype(dtype)
        x = constrain(x, ("batch", "seq", "embed"))

        enc_axes = self.param_axes()["enc"]

        def body(x, lp):
            lp = constrain_tree(lp, enc_axes, drop_leading=1)
            h = layernorm(x, lp["ln1"], cfg.rms_eps)
            q, k, v = qkv(lp["attn"], h, cfg, None, None)
            ao = attn_lib.full_attention(q, k, v, causal=False, q_chunk=2048)
            x = x + jnp.einsum(
                "bth,hd->btd", ao.reshape(*ao.shape[:2], -1), lp["attn"]["wo"].astype(x.dtype)
            )
            h2 = layernorm(x, lp["ln2"], cfg.rms_eps)
            x = x + apply_mlp(lp["mlp"], h2, cfg)
            return constrain(x, ("batch", "seq", "embed")), None

        x, _ = jax.lax.scan(body, x, params["enc"])
        return layernorm(x, params["enc_ln_f"], cfg.rms_eps)

    # -- decoder ----------------------------------------------------------
    def _cross_kv(self, params, enc_out):
        """Precompute per-layer cross-attention K/V. → (L,B,S_enc,K,hd)×2"""
        cfg = self.cfg
        K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        B, S, _ = enc_out.shape

        def body(_, lp):
            k = jnp.einsum("btd,dh->bth", enc_out, lp["wk"].astype(enc_out.dtype))
            v = jnp.einsum("btd,dh->bth", enc_out, lp["wv"].astype(enc_out.dtype))
            if cfg.attention_bias:
                k = k + lp["bk"].astype(k.dtype)
                v = v + lp["bv"].astype(v.dtype)
            return None, (k.reshape(B, S, K, hd), v.reshape(B, S, K, hd))

        _, (ck, cv) = jax.lax.scan(body, None, params["dec"]["cross"])
        return ck, cv

    def _decoder(self, params, tokens, enc_out, pos_offset=0):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        B, T = tokens.shape
        x = embed_tokens(params["embed"], tokens, dtype)
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos_offset, T, 0).astype(dtype)
        ck, cv = self._cross_kv(params, enc_out)

        dec_axes = self.param_axes()["dec"]

        def body(x, inputs):
            lp, ckl, cvl = inputs
            lp = constrain_tree(lp, dec_axes, drop_leading=1)
            h = layernorm(x, lp["ln1"], cfg.rms_eps)
            q, k, v = qkv(lp["attn"], h, cfg, None, None)
            ao = attn_lib.full_attention(q, k, v, causal=True, q_chunk=2048)
            x = x + jnp.einsum(
                "bth,hd->btd", ao.reshape(*ao.shape[:2], -1), lp["attn"]["wo"].astype(x.dtype)
            )
            h2 = layernorm(x, lp["ln2"], cfg.rms_eps)
            qc = jnp.einsum("btd,dh->bth", h2, lp["cross"]["wq"].astype(h2.dtype))
            if cfg.attention_bias:
                qc = qc + lp["cross"]["bq"].astype(qc.dtype)
            qc = qc.reshape(B, T, cfg.n_heads, cfg.resolved_head_dim)
            co = attn_lib.full_attention(qc, ckl, cvl, causal=False, q_chunk=2048)
            x = x + jnp.einsum(
                "bth,hd->btd", co.reshape(*co.shape[:2], -1), lp["cross"]["wo"].astype(x.dtype)
            )
            h3 = layernorm(x, lp["ln3"], cfg.rms_eps)
            x = x + apply_mlp(lp["mlp"], h3, cfg)
            return constrain(x, ("batch", "seq", "embed")), None

        x, _ = jax.lax.scan(body, x, (params["dec"], ck, cv))
        return layernorm(x, params["dec_ln_f"], cfg.rms_eps)

    # -- public api -----------------------------------------------------------
    def forward(self, params, tokens, enc_embeds=None, *, remat=False, q_chunk=0):
        cfg = self.cfg
        if enc_embeds is None:
            enc_embeds = jnp.zeros(
                (tokens.shape[0], cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        enc_out = self.encode(params, enc_embeds)
        x = self._decoder(params, tokens, enc_out)
        return logits_from_hidden(x, params["embed"], cfg.vocab), jnp.zeros((), jnp.float32)

    def loss(self, params, batch, *, remat=True, q_chunk=0):
        logits, _ = self.forward(
            params, batch["tokens"], batch.get("enc_embeds"), remat=remat
        )
        loss, metrics = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
        return loss, metrics

    # -- serving -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, K, hd), jnp.bfloat16),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, K, hd), jnp.bfloat16),
            "ck": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, K, hd), jnp.bfloat16),
            "cv": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, K, hd), jnp.bfloat16),
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        return {
            "k": Axes("layers", "cache_batch", "kv_seq", "act_kv", None),
            "v": Axes("layers", "cache_batch", "kv_seq", "act_kv", None),
            "ck": Axes("layers", "cache_batch", None, "act_kv", None),
            "cv": Axes("layers", "cache_batch", None, "act_kv", None),
            "length": Axes(),
        }

    def prefill(self, params, tokens, enc_embeds=None, *, pad_to=None, q_chunk=0):
        cfg = self.cfg
        B, T = tokens.shape
        if enc_embeds is None:
            enc_embeds = jnp.zeros((B, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
        enc_out = self.encode(params, enc_embeds)
        ck, cv = self._cross_kv(params, enc_out)
        dtype = jnp.dtype(cfg.dtype)
        x = embed_tokens(params["embed"], tokens, dtype)
        x = x + params["dec_pos"][:T].astype(dtype)
        K, hd = cfg.n_kv_heads, cfg.resolved_head_dim

        def body(x, inputs):
            lp, ckl, cvl = inputs
            h = layernorm(x, lp["ln1"], cfg.rms_eps)
            q, k, v = qkv(lp["attn"], h, cfg, None, None)
            ao = attn_lib.full_attention(q, k, v, causal=True, q_chunk=2048)
            x = x + jnp.einsum(
                "bth,hd->btd", ao.reshape(*ao.shape[:2], -1), lp["attn"]["wo"].astype(x.dtype)
            )
            h2 = layernorm(x, lp["ln2"], cfg.rms_eps)
            qc = jnp.einsum("btd,dh->bth", h2, lp["cross"]["wq"].astype(h2.dtype))
            if cfg.attention_bias:
                qc = qc + lp["cross"]["bq"].astype(qc.dtype)
            qc = qc.reshape(B, T, cfg.n_heads, hd)
            co = attn_lib.full_attention(qc, ckl, cvl, causal=False, q_chunk=2048)
            x = x + jnp.einsum(
                "bth,hd->btd", co.reshape(*co.shape[:2], -1), lp["cross"]["wo"].astype(x.dtype)
            )
            h3 = layernorm(x, lp["ln3"], cfg.rms_eps)
            x = x + apply_mlp(lp["mlp"], h3, cfg)
            return constrain(x, ("batch", "seq", "embed")), (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["dec"], ck, cv))
        x = layernorm(x, params["dec_ln_f"], cfg.rms_eps)
        logits = logits_from_hidden(x[:, -1:], params["embed"], cfg.vocab)[:, 0]
        if pad_to is not None and pad_to > T:
            pad = [(0, 0), (0, 0), (0, pad_to - T), (0, 0), (0, 0)]
            ks = jnp.pad(ks, pad)
            vs = jnp.pad(vs, pad)
        cache = {
            "k": ks.astype(jnp.bfloat16),
            "v": vs.astype(jnp.bfloat16),
            "ck": ck.astype(jnp.bfloat16),
            "cv": cv.astype(jnp.bfloat16),
            "length": jnp.asarray(T, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        B = tokens.shape[0]
        pos = cache["length"]
        hd = cfg.resolved_head_dim
        x = embed_tokens(params["embed"], tokens, dtype)
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0).astype(dtype)

        dec_axes = self.param_axes()["dec"]

        def body(x, inputs):
            lp, kc, vc, ckl, cvl = inputs
            lp = constrain_tree(lp, dec_axes, drop_leading=1)
            h = layernorm(x, lp["ln1"], cfg.rms_eps)
            q, k, v = qkv(lp["attn"], h, cfg, None, None)
            kc = attn_lib.update_cache(kc, k, pos)
            vc = attn_lib.update_cache(vc, v, pos)
            ao = attn_lib.decode_attention(q, kc, vc, pos + 1)
            x = x + jnp.einsum(
                "bth,hd->btd", ao.reshape(B, 1, -1), lp["attn"]["wo"].astype(x.dtype)
            )
            h2 = layernorm(x, lp["ln2"], cfg.rms_eps)
            qc = jnp.einsum("btd,dh->bth", h2, lp["cross"]["wq"].astype(h2.dtype))
            if cfg.attention_bias:
                qc = qc + lp["cross"]["bq"].astype(qc.dtype)
            qc = qc.reshape(B, 1, cfg.n_heads, hd)
            co = attn_lib.decode_attention(
                qc, ckl, cvl, jnp.asarray(ckl.shape[1], jnp.int32)
            )
            x = x + jnp.einsum(
                "bth,hd->btd", co.reshape(B, 1, -1), lp["cross"]["wo"].astype(x.dtype)
            )
            h3 = layernorm(x, lp["ln3"], cfg.rms_eps)
            x = x + apply_mlp(lp["mlp"], h3, cfg)
            return constrain(x, ("batch", "seq", "embed")), (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"], cache["ck"], cache["cv"])
        )
        x = layernorm(x, params["dec_ln_f"], cfg.rms_eps)
        logits = logits_from_hidden(x, params["embed"], cfg.vocab)[:, 0]
        return logits, {
            "k": k_new,
            "v": v_new,
            "ck": cache["ck"],
            "cv": cache["cv"],
            "length": pos + 1,
        }
