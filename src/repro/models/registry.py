"""Model registry: config → model instance."""
from __future__ import annotations

from .mamba2 import Mamba2LM
from .rglru import GriffinLM
from .transformer import TransformerLM
from .whisper import WhisperModel


def build_model(cfg):
    if cfg.family == "ssm":
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        return GriffinLM(cfg)
    if cfg.family == "audio":
        return WhisperModel(cfg)
    return TransformerLM(cfg)  # dense | moe | vlm
