"""BVLSM-style paged KV cache (DESIGN.md §2, Layer B).

The mapping onto the paper:

* page pool (P, page, K, hd) arrays = the **BValue arena** (big values),
* per-sequence page table (int32 page ids) = the **Key-ValueOffset**
  metadata — tiny, hot, and the only thing the scheduler mutates,
* allocator free-list = BValue file/offset reservation,
* ``HostPageCache`` = **BVCache**: a fixed-capacity MRWF deque holding
  pages evicted from the device arena (host offload), unpinned once
  persisted — identical semantics to core/bvcache.py but for KV pages.

``kernels.paged_decode`` consumes exactly these structures on TPU.
"""
from __future__ import annotations

import io
from collections import OrderedDict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import KVStore


class OutOfPages(RuntimeError):
    pass


@dataclass
class SeqInfo:
    seq_id: int
    length: int = 0
    pages: list[int] = field(default_factory=list)


class PagedKVCache:
    def __init__(
        self,
        num_pages: int,
        page_size: int,
        n_layers: int,
        n_kv_heads: int,
        head_dim: int,
        max_pages_per_seq: int,
        dtype=jnp.bfloat16,
    ):
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        # one arena per layer: (P, page, K, hd)
        shape = (num_pages, page_size, n_kv_heads, head_dim)
        self.pages_k = [jnp.zeros(shape, dtype) for _ in range(n_layers)]
        self.pages_v = [jnp.zeros(shape, dtype) for _ in range(n_layers)]
        self.free: list[int] = list(range(num_pages - 1, -1, -1))
        self.seqs: dict[int, SeqInfo] = {}

    # -- allocator (the ValueOffset reservation) ---------------------------
    def admit(self, seq_id: int, prompt_len: int = 0) -> SeqInfo:
        info = SeqInfo(seq_id)
        self.seqs[seq_id] = info
        if prompt_len:
            self.reserve(seq_id, prompt_len)
        return info

    def reserve(self, seq_id: int, new_tokens: int) -> list[int]:
        info = self.seqs[seq_id]
        need_pages = -(-(info.length + new_tokens) // self.page_size) - len(info.pages)
        newly = []
        for _ in range(need_pages):
            if not self.free:
                raise OutOfPages(f"seq {seq_id}: arena exhausted")
            if len(info.pages) >= self.max_pages_per_seq:
                raise OutOfPages(f"seq {seq_id}: page-table overflow")
            pid = self.free.pop()
            info.pages.append(pid)
            newly.append(pid)
        info.length += new_tokens
        return newly

    def release(self, seq_id: int) -> None:
        info = self.seqs.pop(seq_id)
        self.free.extend(info.pages)

    # -- batch views for the kernels --------------------------------------
    def page_table(self, seq_ids: list[int]) -> np.ndarray:
        table = np.zeros((len(seq_ids), self.max_pages_per_seq), np.int32)
        for row, sid in enumerate(seq_ids):
            pages = self.seqs[sid].pages
            table[row, : len(pages)] = pages
        return table

    def lengths(self, seq_ids: list[int]) -> np.ndarray:
        return np.array([self.seqs[s].length for s in seq_ids], np.int32)

    # -- writes (the BValue put) -------------------------------------------
    def write_token(self, layer: int, seq_ids: list[int], k: jax.Array, v: jax.Array) -> None:
        """k/v: (B, K, hd) for the token just computed (position = length-1)."""
        pk, pv = self.pages_k[layer], self.pages_v[layer]
        for row, sid in enumerate(seq_ids):
            info = self.seqs[sid]
            pos = info.length - 1
            pid = info.pages[pos // self.page_size]
            off = pos % self.page_size
            pk = pk.at[pid, off].set(k[row])
            pv = pv.at[pid, off].set(v[row])
        self.pages_k[layer], self.pages_v[layer] = pk, pv

    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.num_pages


class HostPageCache:
    """BVCache for offloaded pages: MRWF admission, LRU eviction, pinning
    for pages whose host write-back hasn't completed."""

    def __init__(self, capacity_pages: int):
        self.capacity = capacity_pages
        self._map: OrderedDict[tuple, tuple[np.ndarray, bool]] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def put(self, key: tuple, page: np.ndarray, pinned: bool = False) -> None:
        if key in self._map:
            self._map.pop(key)
        self._map[key] = (page, pinned)
        self._map.move_to_end(key)
        while len(self._map) > self.capacity:
            for k in list(self._map):
                if not self._map[k][1]:
                    self._map.pop(k)
                    break
            else:
                break  # everything pinned

    def unpin(self, key: tuple) -> None:
        if key in self._map:
            page, _ = self._map[key]
            self._map[key] = (page, False)

    def get(self, key: tuple) -> np.ndarray | None:
        hit = self._map.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        self._map.move_to_end(key)
        return hit[0]


class PageSpillStore:
    """Durable tier below :class:`HostPageCache`: pages evicted from host
    RAM spill into any :class:`~repro.core.api.KVStore` — one engine
    (``DB``) or a sharded one (``ShardedDB``), the serving stack doesn't
    care. A KV page is exactly the paper's big value, so spills ride the
    WAL-time separated value path; ``restore_many`` uses the store's
    batched ``multi_get`` (per-shard bloom-probe batching under a
    sharded store). Pages serialize via ``np.save`` (self-describing
    dtype/shape, no pickle)."""

    def __init__(self, store: KVStore, prefix: bytes = b"kvpage/"):
        self.store = store
        self.prefix = prefix

    def _key(self, key: tuple) -> bytes:
        return self.prefix + "/".join(str(p) for p in key).encode()

    def spill(self, key: tuple, page: np.ndarray) -> None:
        buf = io.BytesIO()
        np.save(buf, np.ascontiguousarray(page), allow_pickle=False)
        self.store.put(self._key(key), buf.getvalue())

    def restore(self, key: tuple) -> np.ndarray | None:
        raw = self.store.get(self._key(key))
        if raw is None:
            return None
        return np.load(io.BytesIO(raw), allow_pickle=False)

    def restore_many(self, keys: list[tuple]) -> list[np.ndarray | None]:
        raws = self.store.multi_get([self._key(k) for k in keys])
        return [
            None if r is None else np.load(io.BytesIO(r), allow_pickle=False)
            for r in raws
        ]
