"""Continuous-batching serving engine over the paged KV cache.

Requests queue up; each engine step (1) admits pending requests while pages
remain (prefill builds their cache), (2) decodes one token for every active
sequence in a single batched ``decode_step``, (3) retires finished
sequences and frees their pages. The page-table indirection (the paper's
Key-ValueOffset) is what makes admission/eviction O(1) metadata ops rather
than cache copies.

This engine drives the *contiguous-cache* decode path of the models
(models/*.decode_step) batched over active sequences; the Pallas
``paged_decode`` kernel is the TPU hot path consuming the same page tables
(exercised in examples/serve_paged.py and tests).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from .kv_cache import OutOfPages, PagedKVCache


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    submitted_at: float = field(default_factory=time.monotonic)
    tokens: list[int] = field(default_factory=list)
    first_token_at: float | None = None
    done_at: float | None = None


class ServingEngine:
    def __init__(self, model_cfg, params, max_batch: int = 8, max_len: int = 512,
                 page_size: int = 64):
        self.cfg = model_cfg
        self.model = build_model(model_cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.page_size = page_size
        self.kv = PagedKVCache(
            num_pages=max_batch * (max_len // page_size + 1) * 2,
            page_size=page_size,
            n_layers=model_cfg.n_layers,
            n_kv_heads=max(model_cfg.n_kv_heads, 1),
            head_dim=model_cfg.resolved_head_dim,
            max_pages_per_seq=max_len // page_size + 1,
        )
        self.pending: list[Request] = []
        self.active: dict[int, Request] = {}
        self.caches: dict[int, dict] = {}  # per-seq model cache (contiguous path)
        self.finished: list[Request] = []
        self._decode = jax.jit(lambda p, c, t: self.model.decode_step(p, c, t))
        self._prefill = jax.jit(lambda p, t: self.model.prefill(p, t, pad_to=self.max_len))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _admit(self) -> None:
        while self.pending and len(self.active) < self.max_batch:
            req = self.pending[0]
            try:
                self.kv.admit(req.req_id, len(req.prompt))
            except OutOfPages:
                break
            self.pending.pop(0)
            logits, cache = self._prefill(self.params, jnp.asarray(req.prompt)[None])
            tok = int(jnp.argmax(logits[0]))
            req.tokens.append(tok)
            req.first_token_at = time.monotonic()
            self.kv.reserve(req.req_id, 1)
            self.active[req.req_id] = req
            self.caches[req.req_id] = cache

    def _retire(self, req: Request) -> None:
        req.done_at = time.monotonic()
        self.kv.release(req.req_id)
        self.caches.pop(req.req_id)
        self.active.pop(req.req_id)
        self.finished.append(req)

    def step(self) -> int:
        """One engine iteration; returns number of tokens produced."""
        self._admit()
        if not self.active:
            return 0
        produced = 0
        for sid in list(self.active):
            req = self.active[sid]
            cache = self.caches[sid]
            last = jnp.asarray([[req.tokens[-1]]], jnp.int32)
            logits, cache = self._decode(self.params, cache, last)
            self.caches[sid] = cache
            tok = int(jnp.argmax(logits[0]))
            req.tokens.append(tok)
            produced += 1
            try:
                self.kv.reserve(sid, 1)
            except OutOfPages:
                self._retire(req)
                continue
            if len(req.tokens) >= req.max_new_tokens or int(cache["length"]) >= self.max_len - 1:
                self._retire(req)
        return produced

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.pending and not self.active:
                break
            self.step()
        return self.finished

    def metrics(self) -> dict:
        lat = [r.done_at - r.submitted_at for r in self.finished if r.done_at]
        ttft = [r.first_token_at - r.submitted_at for r in self.finished if r.first_token_at]
        toks = sum(len(r.tokens) for r in self.finished)
        return {
            "requests": len(self.finished),
            "tokens": toks,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
            "kv_utilization": self.kv.utilization(),
        }
